//! Data-parallel helpers (rayon is unavailable offline).
//!
//! Two tiers:
//!
//! * [`par_map`] — scoped-thread fork/join for cold paths that want a
//!   `Vec` of results (tuner sweeps, figure harness). Spawns threads per
//!   call, so it allocates.
//! * [`pool`] / [`ThreadPool::run`] — a persistent *sharded* worker pool
//!   whose dispatch performs **zero heap allocation**: the steady-state
//!   stencil time loop ([`crate::stencil::exec`]) runs on it. Workers park
//!   on a condvar between jobs and steal items off a shared atomic
//!   counter, so uneven per-item cost (e.g. pruned stencil rows) balances.
//!
//! # Shards
//!
//! The pool is partitioned into disjoint **shards**: each shard owns its
//! own worker set, job slot, and steal counter, so a dispatch on one shard
//! never contends with a dispatch on another. Historically the pool had a
//! single dispatch gate and a second concurrent `run()` — two steppers
//! stepping at once, a tuner probe overlapping a bench — hit `try_lock`
//! `WouldBlock` and silently degraded to inline serial execution. Now an
//! unbound [`ThreadPool::run`] probes shards starting at shard 0 and
//! dispatches on the first free one, so concurrent top-level dispatches
//! land on disjoint shards and *both* run multi-threaded; the old global
//! API is therefore "shard 0 plus failover". Inline serial execution
//! remains the final fallback when every probed shard is busy (e.g. a
//! nested `run()` from inside a job at full saturation), which is what
//! keeps the pool deadlock-free.
//!
//! Multi-tenant callers (the batched job service,
//! `coordinator::service`) pin a thread to one shard with [`bind_shard`]:
//! bound dispatches use only that shard, keeping concurrent stencil
//! streams cache-disjoint instead of interleaved on shared workers.
//!
//! Both tiers honour `STENCILAX_THREADS` (read per call via
//! [`num_threads`]); the global pool's shard count honours
//! `STENCILAX_SHARDS` (default [`DEFAULT_SHARDS`]).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

/// Number of worker threads: `STENCILAX_THREADS` or the machine parallelism.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("STENCILAX_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` in parallel, preserving order of results.
///
/// Work-stealing via a shared atomic counter: threads grab indices until
/// exhausted, so uneven per-item cost (e.g. pruned stencil rows) balances.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    // Workers collect (index, value) pairs, scattered into place afterwards.
    let next = AtomicUsize::new(0);
    let pairs: Vec<(usize, T)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in pairs {
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.expect("missing index")).collect()
}

/// Parallel for-each over `0..n` (no results).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_map(n, |i| f(i));
}

// ---------------------------------------------------------------------------
// Persistent sharded worker pool with allocation-free dispatch
// ---------------------------------------------------------------------------

/// Type-erased borrowed job. The pointee lives on the dispatching caller's
/// stack; a dispatch blocks until every worker has left the job before
/// returning, which is what makes the lifetime erasure sound (the same
/// argument as `std::thread::scope`).
type JobRef = &'static (dyn Fn(usize) + Sync);

struct Slot {
    /// Bumped once per dispatch; workers detect new jobs by epoch change.
    epoch: u64,
    job: Option<JobRef>,
    n_items: usize,
    /// Worker threads participating in the current job (ids `0..participants`).
    participants: usize,
    /// Participating workers that have not yet finished the current job.
    running: usize,
}

struct Shared {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
    /// Work-stealing cursor over `0..n_items`.
    next: AtomicUsize,
    /// Set when a worker's job item panicked (re-raised by the caller).
    panicked: AtomicBool,
    /// First panicking worker's payload message, carried back so the
    /// caller's re-raise (and the serving layer's `failed` events) keep
    /// the original diagnostic instead of a generic "worker panicked".
    panic_msg: Mutex<Option<String>>,
    /// Telemetry (DESIGN.md §18): dispatches on this shard, participants
    /// summed over them, and the item split between the dispatching
    /// caller and the stealing workers. Each participant accumulates its
    /// item count locally and folds it in with ONE relaxed `fetch_add`
    /// per dispatch, so the steal loop itself stays atomic-free.
    dispatches: AtomicU64,
    participants_total: AtomicU64,
    caller_items: AtomicU64,
    stolen_items: AtomicU64,
}

/// Best-effort text of a panic payload (`&str` / `String` payloads; the
/// overwhelmingly common cases from `panic!`, `assert!`, and `expect`).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Ignore mutex poisoning: the pool's own critical sections contain no user
/// code, and a panicking job is re-raised by the dispatching caller anyway.
fn lock_slot(shared: &Shared) -> MutexGuard<'_, Slot> {
    shared.slot.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait_on<'a>(cv: &Condvar, guard: MutexGuard<'a, Slot>) -> MutexGuard<'a, Slot> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// One pool shard: a private worker set, job slot, and steal counter.
/// Dispatches on different shards share nothing but the process.
struct Shard {
    shared: Arc<Shared>,
    /// Serializes dispatches *on this shard only*. `try_lock` failure
    /// (another dispatch already in flight here, including a nested call
    /// from inside a job) makes the caller probe the next shard — or run
    /// inline when no shard is free — so the pool can never deadlock.
    gate: Mutex<()>,
    /// Upper bound on this shard's worker threads.
    max_workers: usize,
    /// Workers spawned so far (ids `0..spawned`, contiguous). Demand
    /// driven: a dispatch spawns only the workers it will actually use,
    /// so unused shards (and fully serial runs) never cost a thread, and
    /// a shard serving budget-capped tenants never spawns its full
    /// complement. Mutated only under `gate`, but kept in a Mutex so the
    /// invariant doesn't rest on that.
    spawned: Mutex<usize>,
    index: usize,
}

impl Shard {
    fn new(index: usize, workers: usize) -> Shard {
        Shard {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot {
                    epoch: 0,
                    job: None,
                    n_items: 0,
                    participants: 0,
                    running: 0,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
                next: AtomicUsize::new(0),
                panicked: AtomicBool::new(false),
                panic_msg: Mutex::new(None),
                dispatches: AtomicU64::new(0),
                participants_total: AtomicU64::new(0),
                caller_items: AtomicU64::new(0),
                stolen_items: AtomicU64::new(0),
            }),
            gate: Mutex::new(()),
            max_workers: workers,
            spawned: Mutex::new(0),
            index,
        }
    }

    /// Make at least `want` workers exist (clamped to `max_workers`);
    /// returns how many exist afterwards.
    fn ensure_workers(&self, want: usize) -> usize {
        let want = want.min(self.max_workers);
        let mut n = self.spawned.lock().unwrap_or_else(|e| e.into_inner());
        while *n < want {
            let sh = Arc::clone(&self.shared);
            let id = *n;
            std::thread::Builder::new()
                .name(format!("stencilax-pool-{}-{id}", self.index))
                .spawn(move || worker_loop(&sh, id))
                .expect("spawning pool worker");
            *n += 1;
        }
        *n
    }

    /// Dispatch with this shard's gate already held. Returns the number of
    /// participating threads (caller included).
    fn dispatch(
        &self,
        _gate: MutexGuard<'_, ()>,
        n: usize,
        threads: usize,
        f: &(dyn Fn(usize) + Sync),
    ) -> usize {
        let want = threads.min(self.max_workers + 1).min(n);
        if want <= 1 {
            for i in 0..n {
                f(i);
            }
            self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
            self.shared.participants_total.fetch_add(1, Ordering::Relaxed);
            self.shared.caller_items.fetch_add(n as u64, Ordering::Relaxed);
            return 1;
        }
        // `want - 1 <= max_workers`, so ensure_workers returns at least
        // `want - 1`; the min caps participation at the thread budget when
        // earlier, wider dispatches already spawned more workers.
        let parts = want.min(self.ensure_workers(want - 1) + 1);
        // SAFETY: the reference escapes only to this shard's workers, and
        // the DispatchGuard below blocks (even on unwind) until
        // `running == 0`, i.e. until no worker can touch it any more.
        let job: JobRef =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), JobRef>(f) };
        self.shared.panicked.store(false, Ordering::Relaxed);
        {
            let mut s = lock_slot(&self.shared);
            s.epoch += 1;
            s.job = Some(job);
            s.n_items = n;
            s.participants = parts - 1; // the caller is the final participant
            s.running = parts - 1;
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.work.notify_all();
        }
        let guard = DispatchGuard { shared: &self.shared };
        let mut taken = 0u64;
        loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
            taken += 1;
        }
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
        self.shared.participants_total.fetch_add(parts as u64, Ordering::Relaxed);
        self.shared.caller_items.fetch_add(taken, Ordering::Relaxed);
        drop(guard); // waits for the workers, then clears the job
        if self.shared.panicked.load(Ordering::Relaxed) {
            let msg = self
                .shared
                .panic_msg
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .unwrap_or_default();
            panic!("pool worker panicked: {msg}");
        }
        parts
    }
}

/// Persistent sharded worker pool. One process-wide instance lives behind
/// [`pool`]; dedicated instances exist only in tests.
pub struct ThreadPool {
    shards: Vec<Shard>,
}

/// Cumulative work-stealing telemetry for one pool shard (DESIGN.md §18):
/// how often the shard dispatched, how many threads those dispatches
/// engaged, and how the executed items split between the dispatching
/// caller and the stealing workers — the live evidence that concurrent
/// streams really run multi-threaded instead of collapsing to serial.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Dispatches that acquired this shard's gate (serial ones included).
    pub dispatches: u64,
    /// Participant threads summed over those dispatches (caller included).
    pub participants: u64,
    /// Items executed by dispatching callers.
    pub caller_items: u64,
    /// Items stolen and executed by this shard's worker threads.
    pub stolen_items: u64,
}

impl ThreadPool {
    /// Spawn a single-shard pool with `workers` worker threads — the
    /// historical constructor, equivalent to `sharded(1, workers)`.
    pub fn new(workers: usize) -> Self {
        Self::sharded(1, workers)
    }

    /// A pool with `shards` disjoint shards of `workers_per_shard` worker
    /// threads each. Workers spawn lazily on each shard's first parallel
    /// dispatch.
    pub fn sharded(shards: usize, workers_per_shard: usize) -> Self {
        Self {
            shards: (0..shards.max(1)).map(|i| Shard::new(i, workers_per_shard)).collect(),
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Worker-thread capacity per shard (every shard is sized
    /// identically; actual workers spawn on demand up to this bound).
    pub fn workers_per_shard(&self) -> usize {
        self.shards[0].max_workers
    }

    /// Point-in-time telemetry for one shard (index modulo the shard
    /// count): cumulative dispatches, participant threads summed over
    /// them, and the executed-item split between dispatching callers and
    /// stealing workers. Inline-serial fallbacks that never acquired a
    /// shard gate are not attributed to any shard.
    pub fn shard_stats(&self, shard: usize) -> ShardStats {
        let sh = &self.shards[shard % self.shards.len()].shared;
        ShardStats {
            dispatches: sh.dispatches.load(Ordering::Relaxed),
            participants: sh.participants_total.load(Ordering::Relaxed),
            caller_items: sh.caller_items.load(Ordering::Relaxed),
            stolen_items: sh.stolen_items.load(Ordering::Relaxed),
        }
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing across up to
    /// `threads` threads (the caller participates as one of them). Performs
    /// no heap allocation. Returns the number of threads that participated
    /// in the dispatch (1 when it ran inline serial).
    ///
    /// Shard routing: a thread bound via [`bind_shard`] dispatches only on
    /// its own shard; an unbound caller probes shards starting at 0 and
    /// takes the first free one, so concurrent dispatches spread across
    /// shards instead of collapsing to serial. Falls back to inline serial
    /// execution when `threads <= 1`, `n <= 1`, or every probed shard is
    /// already mid-dispatch.
    pub fn run(&self, n: usize, threads: usize, f: &(dyn Fn(usize) + Sync)) -> usize {
        match bound_shard() {
            Some(s) => self.run_probing(s % self.shards.len(), 1, n, threads, f),
            None => self.run_probing(0, self.shards.len(), n, threads, f),
        }
    }

    /// [`Self::run`] pinned to one shard (index taken modulo the shard
    /// count): never touches any other shard's workers, running inline
    /// serial instead when that shard is busy. Multi-tenant callers use
    /// this (via [`bind_shard`]) to keep concurrent streams cache-disjoint.
    pub fn run_on(
        &self,
        shard: usize,
        n: usize,
        threads: usize,
        f: &(dyn Fn(usize) + Sync),
    ) -> usize {
        self.run_probing(shard % self.shards.len(), 1, n, threads, f)
    }

    fn run_probing(
        &self,
        start: usize,
        probes: usize,
        n: usize,
        threads: usize,
        f: &(dyn Fn(usize) + Sync),
    ) -> usize {
        if n == 0 {
            return 0;
        }
        if threads <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return 1;
        }
        for k in 0..probes {
            let shard = &self.shards[(start + k) % self.shards.len()];
            let gate = match shard.gate.try_lock() {
                Ok(g) => g,
                // a caller that panicked mid-job poisons the gate; the
                // shard state itself is consistent (its guard waited), so
                // reclaim it
                Err(TryLockError::Poisoned(p)) => p.into_inner(),
                Err(TryLockError::WouldBlock) => continue,
            };
            return shard.dispatch(gate, n, threads, f);
        }
        // every probed shard is mid-dispatch (nested call or full
        // saturation): inline serial, so the pool can never deadlock
        for i in 0..n {
            f(i);
        }
        1
    }
}

/// Waits for all participating workers and clears the job slot — runs on
/// the normal path *and* when the caller's own `f(i)` unwinds, so workers
/// never outlive the borrowed closure.
struct DispatchGuard<'a> {
    shared: &'a Shared,
}

impl Drop for DispatchGuard<'_> {
    fn drop(&mut self) {
        let mut s = lock_slot(self.shared);
        while s.running > 0 {
            s = wait_on(&self.shared.done, s);
        }
        s.job = None;
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let (job, n) = {
            let mut s = lock_slot(shared);
            loop {
                if s.epoch != seen {
                    seen = s.epoch;
                    if id < s.participants {
                        break (s.job.expect("job published with epoch"), s.n_items);
                    }
                }
                s = wait_on(&shared.work, s);
            }
        };
        let stole = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut taken = 0u64;
            loop {
                let i = shared.next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                job(i);
                taken += 1;
            }
            shared.stolen_items.fetch_add(taken, Ordering::Relaxed);
        }));
        if let Err(payload) = stole {
            // drain the counter so sibling workers stop early, then report
            // with the original payload (first panicking worker wins)
            shared.next.store(usize::MAX / 2, Ordering::Relaxed);
            let mut msg = shared.panic_msg.lock().unwrap_or_else(|e| e.into_inner());
            msg.get_or_insert_with(|| panic_message(&*payload));
            drop(msg);
            shared.panicked.store(true, Ordering::Relaxed);
        }
        let mut s = lock_slot(shared);
        s.running -= 1;
        if s.running == 0 {
            shared.done.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Shard binding (multi-tenant cache disjointness)
// ---------------------------------------------------------------------------

thread_local! {
    static BOUND_SHARD: Cell<Option<usize>> = Cell::new(None);
}

/// RAII guard restoring the previous shard binding on drop.
pub struct ShardBinding {
    prev: Option<usize>,
}

impl Drop for ShardBinding {
    fn drop(&mut self) {
        BOUND_SHARD.with(|c| c.set(self.prev));
    }
}

/// Pin this thread's pool dispatches to one shard (index taken modulo the
/// pool's shard count at dispatch time). A bound dispatch probes only its
/// own shard — if that shard is busy it runs inline instead of spilling
/// onto other shards, preserving the cache-disjointness the binding exists
/// for. Returns a guard that restores the previous binding when dropped.
pub fn bind_shard(shard: usize) -> ShardBinding {
    ShardBinding { prev: BOUND_SHARD.with(|c| c.replace(Some(shard))) }
}

/// This thread's shard binding, if any (see [`bind_shard`]).
pub fn bound_shard() -> Option<usize> {
    BOUND_SHARD.with(|c| c.get())
}

/// Run one *driver* closure per shard on scoped threads, each pinned to
/// its shard via [`bind_shard`], and return the per-driver results in
/// shard order.
///
/// This is the multi-tenant driver lifecycle both serving front-ends
/// share (`coordinator::service` batch mode and the
/// `coordinator::daemon` online queue): a driver owns its shard for its
/// whole life and loops popping work from some queue. The loop body is
/// the caller's — crucially, a driver blocked on a *momentarily empty but
/// still open* queue (the online case: jobs arrive over a socket while
/// sessions run) simply parks inside `f` without terminating; the scoped
/// join only completes once every driver's `f` returns, i.e. once the
/// queue is closed and drained.
pub fn drive_shards<T: Send, F: Fn(usize) -> T + Sync>(shards: usize, f: F) -> Vec<T> {
    let shards = shards.max(1);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..shards)
            .map(|shard| {
                let f = &f;
                scope.spawn(move || {
                    let _bind = bind_shard(shard);
                    f(shard)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard driver panicked")).collect()
    })
}

// ---------------------------------------------------------------------------
// The process-wide pool
// ---------------------------------------------------------------------------

/// Default shard count of the process-wide pool (`STENCILAX_SHARDS`
/// overrides). Sized for the job service's bench matrix (1/2/4 concurrent
/// sessions); idle shards spawn no threads, so over-provisioning is free.
pub const DEFAULT_SHARDS: usize = 4;

fn env_shards() -> Option<usize> {
    std::env::var("STENCILAX_SHARDS").ok()?.parse::<usize>().ok().map(|n| n.max(1))
}

static POOL: OnceLock<ThreadPool> = OnceLock::new();

fn build_pool(min_shards: usize) -> ThreadPool {
    // An explicit STENCILAX_SHARDS always wins (it is the operator's
    // override, including `=1` to force the historical single-shard
    // behavior); only the default yields to a larger request. Each shard
    // is capped like the historical single pool: never below 3 workers,
    // so `STENCILAX_THREADS=4` is honoured even on small CI runners
    // (workers spawn on demand, so unused capacity costs nothing).
    let shards = env_shards().unwrap_or_else(|| DEFAULT_SHARDS.max(min_shards));
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    ThreadPool::sharded(shards, avail.max(4) - 1)
}

/// The process-wide pool: [`DEFAULT_SHARDS`] shards (or
/// `STENCILAX_SHARDS`), each sized for the machine. Created lazily, and
/// each shard's workers spawn only on its first parallel dispatch: a
/// serial run (`STENCILAX_THREADS=1`) never spawns a thread.
pub fn pool() -> &'static ThreadPool {
    POOL.get_or_init(|| build_pool(1))
}

/// Ask the process-wide pool for at least `n` shards and return the
/// actual shard count. Only effective before the pool's first use — once
/// created, the shard count is fixed — and an explicit `STENCILAX_SHARDS`
/// setting always beats the request; callers must clamp to the returned
/// value (the job service does).
pub fn request_shards(n: usize) -> usize {
    POOL.get_or_init(|| build_pool(n.max(1))).shards()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v = par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn heavy_items_balance() {
        let v = par_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..(if i % 7 == 0 { 100_000 } else { 10 }) {
                acc = acc.wrapping_add(j);
            }
            (i, acc)
        });
        assert_eq!(v.len(), 64);
        for (i, (idx, _)) in v.iter().enumerate() {
            assert_eq!(i, *idx);
        }
    }

    #[test]
    fn par_for_side_effects() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    }

    #[test]
    fn pool_runs_every_item_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::new(3);
        for n in [0usize, 1, 2, 7, 100, 1000] {
            for threads in [1usize, 2, 4, 8] {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                p.run(n, threads, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "n={n} threads={threads} i={i}");
                }
            }
        }
    }

    #[test]
    fn pool_reuses_workers_across_dispatches() {
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::new(2);
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            p.run(64, 3, &|i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * 2016);
    }

    #[test]
    fn pool_nested_dispatch_never_deadlocks() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        // nested run() from inside a job lands on a free shard (or runs
        // inline at full saturation) — it must never deadlock
        pool().run(8, 4, &|_| {
            pool().run(8, 4, &|j| {
                sum.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 8 * 28);
    }

    #[test]
    fn single_shard_nested_dispatch_runs_inline() {
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        let inner_parts = AtomicUsize::new(usize::MAX);
        p.run(4, 4, &|_| {
            let parts = p.run(8, 4, &|j| {
                sum.fetch_add(j as u64, Ordering::Relaxed);
            });
            inner_parts.store(parts, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 28);
        // the single shard's gate was held by the outer dispatch, so the
        // nested one must have reported inline serial execution
        assert_eq!(inner_parts.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "panick")]
    fn pool_propagates_job_panics() {
        let p = ThreadPool::new(2);
        p.run(100, 3, &|i| {
            if i == 37 {
                panic!("job 37 panicked");
            }
        });
    }

    #[test]
    fn pool_panic_carries_the_original_message() {
        // the serving layer converts these into per-job failed events, so
        // the worker's payload must survive the re-raise across threads
        let p = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.run(256, 4, &|i| {
                // many items so a *worker* (not the dispatching caller)
                // reliably draws the poisoned one at least sometimes;
                // either path must carry the message through
                if i == 200 {
                    panic!("item 200 diverged horribly");
                }
                std::thread::sleep(std::time::Duration::from_micros(20));
            });
        }));
        let msg = panic_message(&*caught.expect_err("dispatch must re-raise"));
        assert!(msg.contains("item 200 diverged horribly"), "lost payload: {msg:?}");
        // the pool stays serviceable after the contained panic
        p.run(8, 2, &|_| {});
        assert_eq!(panic_message(&Box::new(42u32)), "panic with non-string payload");
    }

    #[test]
    fn concurrent_dispatches_land_on_disjoint_shards() {
        // The tentpole regression: two OS threads dispatching concurrently
        // must BOTH execute multi-threaded. The old single-gate pool made
        // the second one silently collapse to inline serial.
        use std::collections::HashSet;
        use std::sync::Barrier;
        let p = ThreadPool::sharded(2, 3);
        let go = Barrier::new(2);
        let run_one = |p: &ThreadPool| {
            let ids = Mutex::new(HashSet::new());
            go.wait();
            let parts = p.run(32, 4, &|_i| {
                ids.lock().unwrap().insert(std::thread::current().id());
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
            (parts, ids.into_inner().unwrap().len())
        };
        let (a, b) = std::thread::scope(|s| {
            let ha = s.spawn(|| run_one(&p));
            let hb = s.spawn(|| run_one(&p));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        for (tag, (parts, distinct)) in [("first", a), ("second", b)] {
            assert!(parts > 1, "{tag} dispatch planned {parts} participant(s): serial collapse");
            assert!(distinct > 1, "{tag} dispatch ran on {distinct} thread(s): serial collapse");
        }
    }

    #[test]
    fn run_on_pins_to_one_shard() {
        use std::time::Duration;
        let p = ThreadPool::sharded(2, 3);
        let started = AtomicBool::new(false);
        std::thread::scope(|s| {
            let holder = s.spawn(|| {
                p.run_on(0, 16, 4, &|_| {
                    started.store(true, Ordering::Release);
                    std::thread::sleep(Duration::from_millis(2));
                })
            });
            while !started.load(Ordering::Acquire) {
                std::hint::spin_loop();
            }
            // shard 0 is mid-dispatch: a pinned dispatch must fall back to
            // inline serial (1 participant), never spill onto shard 1 ...
            assert_eq!(p.run_on(0, 8, 4, &|_| {}), 1);
            // ... while pinning to the free shard runs parallel
            assert!(p.run_on(1, 8, 4, &|_| {}) > 1);
            assert!(holder.join().unwrap() > 1);
        });
    }

    #[test]
    fn bind_shard_routes_and_restores() {
        assert_eq!(bound_shard(), None);
        {
            let _outer = bind_shard(1);
            assert_eq!(bound_shard(), Some(1));
            {
                let _inner = bind_shard(0);
                assert_eq!(bound_shard(), Some(0));
            }
            assert_eq!(bound_shard(), Some(1));
        }
        assert_eq!(bound_shard(), None);
        // a bound run still executes every item exactly once
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::sharded(2, 3);
        let _bind = bind_shard(1);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        let parts = p.run(100, 4, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(parts > 1);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn drive_shards_binds_each_driver_and_keeps_order() {
        use std::sync::atomic::AtomicU64;
        let touched = AtomicU64::new(0);
        let out = drive_shards(3, |shard| {
            assert_eq!(bound_shard(), Some(shard), "driver must be pinned to its shard");
            touched.fetch_add(1, Ordering::Relaxed);
            shard * 10
        });
        assert_eq!(out, vec![0, 10, 20]);
        assert_eq!(touched.load(Ordering::Relaxed), 3);
        // a driver that parks (an empty-but-open queue) does not stop its
        // siblings from finishing their own work first
        let out = drive_shards(2, |shard| {
            if shard == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            shard
        });
        assert_eq!(out, vec![0, 1]);
        assert_eq!(drive_shards(0, |s| s), vec![0], "degenerate count clamps to one driver");
    }

    #[test]
    fn shard_stats_account_dispatches_and_item_split() {
        let p = ThreadPool::new(3);
        assert_eq!(p.shard_stats(0), ShardStats::default());
        // parallel dispatch: every item is executed exactly once, and the
        // caller/stolen split covers all of them
        p.run(200, 4, &|_| {
            std::thread::sleep(std::time::Duration::from_micros(20));
        });
        let s = p.shard_stats(0);
        assert_eq!(s.dispatches, 1);
        assert!(s.participants > 1, "{s:?}");
        assert_eq!(s.caller_items + s.stolen_items, 200, "{s:?}");
        assert!(s.stolen_items > 0, "sleepy items must get stolen: {s:?}");
        // a zero-worker shard clamps every dispatch to the caller, and the
        // serial path is attributed too
        let serial = ThreadPool::sharded(1, 0);
        serial.run(8, 4, &|_| {});
        let s2 = serial.shard_stats(0);
        assert_eq!(s2.dispatches, 1);
        assert_eq!(s2.participants, 1);
        assert_eq!(s2.caller_items, 8);
        assert_eq!(s2.stolen_items, 0);
    }

    #[test]
    fn sharded_pool_reports_shape() {
        let p = ThreadPool::sharded(3, 2);
        assert_eq!(p.shards(), 3);
        assert_eq!(p.workers_per_shard(), 2);
        // degenerate shard counts clamp to one shard
        assert_eq!(ThreadPool::sharded(0, 2).shards(), 1);
    }
}
