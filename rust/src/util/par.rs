//! Data-parallel helpers on std scoped threads (rayon is unavailable
//! offline). The stencil engine parallelizes over z-planes exactly like the
//! paper's thread-block decomposition splits its grids.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads: `STENCILAX_THREADS` or the machine parallelism.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("STENCILAX_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` in parallel, preserving order of results.
///
/// Work-stealing via a shared atomic counter: threads grab indices until
/// exhausted, so uneven per-item cost (e.g. pruned stencil rows) balances.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    // Workers collect (index, value) pairs, scattered into place afterwards.
    let next = AtomicUsize::new(0);
    let pairs: Vec<(usize, T)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in pairs {
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.expect("missing index")).collect()
}

/// Parallel for-each over `0..n` (no results).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_map(n, |i| f(i));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v = par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn heavy_items_balance() {
        let v = par_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..(if i % 7 == 0 { 100_000 } else { 10 }) {
                acc = acc.wrapping_add(j);
            }
            (i, acc)
        });
        assert_eq!(v.len(), 64);
        for (i, (idx, _)) in v.iter().enumerate() {
            assert_eq!(i, *idx);
        }
    }

    #[test]
    fn par_for_side_effects() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    }
}
