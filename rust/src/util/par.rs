//! Data-parallel helpers (rayon is unavailable offline).
//!
//! Two tiers:
//!
//! * [`par_map`] — scoped-thread fork/join for cold paths that want a
//!   `Vec` of results (tuner sweeps, figure harness). Spawns threads per
//!   call, so it allocates.
//! * [`pool`] / [`ThreadPool::run`] — a persistent worker pool whose
//!   dispatch performs **zero heap allocation**: the steady-state stencil
//!   time loop ([`crate::stencil::exec`]) runs on it. Workers park on a
//!   condvar between jobs and steal items off a shared atomic counter, so
//!   uneven per-item cost (e.g. pruned stencil rows) balances.
//!
//! Both honour `STENCILAX_THREADS` (read per call via [`num_threads`]).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Number of worker threads: `STENCILAX_THREADS` or the machine parallelism.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("STENCILAX_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` in parallel, preserving order of results.
///
/// Work-stealing via a shared atomic counter: threads grab indices until
/// exhausted, so uneven per-item cost (e.g. pruned stencil rows) balances.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    // Workers collect (index, value) pairs, scattered into place afterwards.
    let next = AtomicUsize::new(0);
    let pairs: Vec<(usize, T)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in pairs {
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.expect("missing index")).collect()
}

/// Parallel for-each over `0..n` (no results).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_map(n, |i| f(i));
}

// ---------------------------------------------------------------------------
// Persistent worker pool with allocation-free dispatch
// ---------------------------------------------------------------------------

/// Type-erased borrowed job. The pointee lives on the dispatching caller's
/// stack; [`ThreadPool::run`] blocks until every worker has left the job
/// before returning, which is what makes the lifetime erasure sound (the
/// same argument as `std::thread::scope`).
type JobRef = &'static (dyn Fn(usize) + Sync);

struct Slot {
    /// Bumped once per dispatch; workers detect new jobs by epoch change.
    epoch: u64,
    job: Option<JobRef>,
    n_items: usize,
    /// Worker threads participating in the current job (ids `0..participants`).
    participants: usize,
    /// Participating workers that have not yet finished the current job.
    running: usize,
}

struct Shared {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
    /// Work-stealing cursor over `0..n_items`.
    next: AtomicUsize,
    /// Set when a worker's job item panicked (re-raised by the caller).
    panicked: AtomicBool,
}

/// Ignore mutex poisoning: the pool's own critical sections contain no user
/// code, and a panicking job is re-raised by the dispatching caller anyway.
fn lock_slot(shared: &Shared) -> MutexGuard<'_, Slot> {
    shared.slot.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait_on<'a>(cv: &Condvar, guard: MutexGuard<'a, Slot>) -> MutexGuard<'a, Slot> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// Persistent worker pool. One process-wide instance lives behind [`pool`];
/// dedicated instances exist only in tests.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: usize,
    /// Serializes dispatches. `try_lock` failure (another dispatch already
    /// in flight, including a nested call from inside a job) falls back to
    /// inline serial execution, so the pool can never deadlock.
    gate: Mutex<()>,
}

impl ThreadPool {
    /// Spawn a pool with `workers` parked worker threads.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                job: None,
                n_items: 0,
                participants: 0,
                running: 0,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        for id in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("stencilax-pool-{id}"))
                .spawn(move || worker_loop(&sh, id))
                .expect("spawning pool worker");
        }
        Self { shared, workers, gate: Mutex::new(()) }
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing across up to
    /// `threads` threads (the caller participates as one of them). Performs
    /// no heap allocation. Falls back to inline serial execution when
    /// `threads <= 1`, `n <= 1`, or another dispatch is already in flight.
    pub fn run(&self, n: usize, threads: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let parts = threads.min(self.workers + 1).min(n);
        if parts <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let _gate = match self.gate.try_lock() {
            Ok(g) => g,
            // a caller that panicked mid-job poisons the gate; the pool
            // state itself is consistent (its guard waited), so reclaim it
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                for i in 0..n {
                    f(i);
                }
                return;
            }
        };
        // SAFETY: the reference escapes only to pool workers, and the
        // DispatchGuard below blocks (even on unwind) until `running == 0`,
        // i.e. until no worker can touch it any more.
        let job: JobRef =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), JobRef>(f) };
        self.shared.panicked.store(false, Ordering::Relaxed);
        {
            let mut s = lock_slot(&self.shared);
            s.epoch += 1;
            s.job = Some(job);
            s.n_items = n;
            s.participants = parts - 1; // the caller is the final participant
            s.running = parts - 1;
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.work.notify_all();
        }
        let guard = DispatchGuard { shared: &self.shared };
        loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }
        drop(guard); // waits for the workers, then clears the job
        if self.shared.panicked.load(Ordering::Relaxed) {
            panic!("pool worker panicked");
        }
    }
}

/// Waits for all participating workers and clears the job slot — runs on
/// the normal path *and* when the caller's own `f(i)` unwinds, so workers
/// never outlive the borrowed closure.
struct DispatchGuard<'a> {
    shared: &'a Shared,
}

impl Drop for DispatchGuard<'_> {
    fn drop(&mut self) {
        let mut s = lock_slot(self.shared);
        while s.running > 0 {
            s = wait_on(&self.shared.done, s);
        }
        s.job = None;
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let (job, n) = {
            let mut s = lock_slot(shared);
            loop {
                if s.epoch != seen {
                    seen = s.epoch;
                    if id < s.participants {
                        break (s.job.expect("job published with epoch"), s.n_items);
                    }
                }
                s = wait_on(&shared.work, s);
            }
        };
        let stole = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            job(i);
        }));
        if stole.is_err() {
            // drain the counter so sibling workers stop early, then report
            shared.next.store(usize::MAX / 2, Ordering::Relaxed);
            shared.panicked.store(true, Ordering::Relaxed);
        }
        let mut s = lock_slot(shared);
        s.running -= 1;
        if s.running == 0 {
            shared.done.notify_all();
        }
    }
}

/// The process-wide pool. Sized for the machine but never below 3 workers,
/// so `STENCILAX_THREADS=4` is honoured even on small CI runners (idle
/// workers just park on the condvar). Created lazily: a serial run
/// (`STENCILAX_THREADS=1`) never spawns it.
pub fn pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(avail.max(4) - 1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v = par_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn heavy_items_balance() {
        let v = par_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..(if i % 7 == 0 { 100_000 } else { 10 }) {
                acc = acc.wrapping_add(j);
            }
            (i, acc)
        });
        assert_eq!(v.len(), 64);
        for (i, (idx, _)) in v.iter().enumerate() {
            assert_eq!(i, *idx);
        }
    }

    #[test]
    fn par_for_side_effects() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    }

    #[test]
    fn pool_runs_every_item_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::new(3);
        for n in [0usize, 1, 2, 7, 100, 1000] {
            for threads in [1usize, 2, 4, 8] {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                p.run(n, threads, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "n={n} threads={threads} i={i}");
                }
            }
        }
    }

    #[test]
    fn pool_reuses_workers_across_dispatches() {
        use std::sync::atomic::AtomicU64;
        let p = ThreadPool::new(2);
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            p.run(64, 3, &|i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * 2016);
    }

    #[test]
    fn pool_nested_dispatch_falls_back_inline() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        // nested run() from inside a job must not deadlock
        pool().run(8, 4, &|_| {
            pool().run(8, 4, &|j| {
                sum.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 8 * 28);
    }

    #[test]
    #[should_panic(expected = "panick")]
    fn pool_propagates_job_panics() {
        let p = ThreadPool::new(2);
        p.run(100, 3, &|i| {
            if i == 37 {
                panic!("job 37 panicked");
            }
        });
    }
}
