//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` deterministic pseudo-random cases;
//! on failure it reports the case index and seed so the exact input can be
//! reproduced by re-running with that seed.

use super::rng::Rng;

/// Run `prop(rng)` for `cases` cases; panic with the failing seed on error.
///
/// The property receives a fresh deterministic RNG per case. Returning
/// `Err(msg)` (or panicking) fails the test with reproduction info.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}",);
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("abs is nonnegative", 100, |rng| {
            let x = rng.normal();
            prop_assert!(x.abs() >= 0.0, "abs({x}) < 0");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failing_case() {
        check("impossible", 10, |rng| {
            let x = rng.uniform();
            prop_assert!(x > 2.0, "uniform {x} not > 2");
            Ok(())
        });
    }
}
