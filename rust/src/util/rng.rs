//! Deterministic RNG (xoshiro256**-style) with normal deviates; used by
//! benchmark input generation (the paper randomizes its input tensors,
//! §5.1) and the property-test helper.

/// Small, fast, deterministic PRNG (xoshiro256**).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal deviate (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let v = r.normal_vec(n);
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
