//! Zero-steady-state-allocation telemetry substrate (DESIGN.md §18).
//!
//! Two primitives, both preallocated at construction so the hot serving
//! paths never touch the allocator (the `alloc_free.rs` pin extends to
//! instrumented runs):
//!
//! - [`Counters`] — a fixed set of relaxed atomics the serving stack
//!   bumps at admission, completion, retry, preemption, and respawn
//!   sites, read point-in-time by the daemon's `stats` endpoint.
//! - [`SpanRing`] — one fixed-capacity ring of monotonic-clock spans per
//!   shard (plus a control track for admissions). A record is one
//!   `fetch_add` on the cursor and four relaxed stores into the slot; a
//!   sequence stamp written last (release) lets the reader discard slots
//!   torn by concurrent wrap-around instead of emitting garbage.
//!
//! [`Telemetry::write_chrome_trace`] serializes the rings as Chrome
//! trace-event JSON (`stencilax-trace/1`): one `pid 0` process, one
//! `tid` per shard track plus a control track, `ph:"X"` duration events
//! for on-shard work (depth-chunk run, finiteness probe, preemption
//! park, retry backoff, digest), `ph:"b"/"e"` async pairs for
//! queue-scoped intervals (admit, queue-wait) that overlap arbitrarily,
//! and `ph:"i"` instants for faults, preemptions, and driver respawns —
//! loadable in Perfetto / `chrome://tracing` as-is.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Schema tag stamped into the trace file's `otherData`.
pub const TRACE_SCHEMA: &str = "stencilax-trace/1";
/// Span slots per track. Power of two so the wrap modulo is a mask;
/// 4096 spans ≈ hours of serving at per-chunk granularity before wrap.
pub const RING_SPANS: usize = 4096;

/// What one span (or instant) measured. The discriminant is packed into
/// the ring slot, so variants must stay ≤ 255.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Admission: validate + plan lookup + cost estimate (control track).
    Admit = 0,
    /// Submit-to-first-dispatch wait (async: overlaps other jobs' waits).
    QueueWait = 1,
    /// One depth-chunk advance on a shard.
    Chunk = 2,
    /// Finiteness probe after a chunk.
    Probe = 3,
    /// Host session parked while a shorter job preempts it.
    Park = 4,
    /// Retry backoff sleep before a re-attempt.
    Backoff = 5,
    /// FNV digest over the output field.
    Digest = 6,
    /// Instant: a session failed (fault surfaced).
    Fault = 7,
    /// Instant: a running session was preempted.
    Preempt = 8,
    /// Instant: a shard driver respawned after a pool-level escape.
    Respawn = 9,
}

impl SpanKind {
    pub const ALL: [SpanKind; 10] = [
        SpanKind::Admit,
        SpanKind::QueueWait,
        SpanKind::Chunk,
        SpanKind::Probe,
        SpanKind::Park,
        SpanKind::Backoff,
        SpanKind::Digest,
        SpanKind::Fault,
        SpanKind::Preempt,
        SpanKind::Respawn,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue-wait",
            SpanKind::Chunk => "chunk",
            SpanKind::Probe => "probe",
            SpanKind::Park => "park",
            SpanKind::Backoff => "backoff",
            SpanKind::Digest => "digest",
            SpanKind::Fault => "fault",
            SpanKind::Preempt => "preempt",
            SpanKind::Respawn => "respawn",
        }
    }

    fn from_u8(v: u8) -> Option<SpanKind> {
        SpanKind::ALL.into_iter().find(|k| *k as u8 == v)
    }

    /// Zero-duration marks rendered as `ph:"i"` instants.
    pub fn is_instant(self) -> bool {
        matches!(self, SpanKind::Fault | SpanKind::Preempt | SpanKind::Respawn)
    }

    /// Intervals that overlap freely (a queue holds many waiters at
    /// once), rendered as `ph:"b"/"e"` async pairs instead of stack
    /// events — the `ph:"X"` events on each track stay strictly nested.
    pub fn is_async(self) -> bool {
        matches!(self, SpanKind::Admit | SpanKind::QueueWait)
    }
}

/// One decoded span, as read back out of a ring.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    /// Job id the span belongs to.
    pub job: u32,
    /// Track the span was recorded on (shard index; `shards` = control).
    pub track: u32,
    /// Microseconds since the [`Telemetry`] epoch.
    pub t0_us: u64,
    pub t1_us: u64,
}

/// One preallocated span slot: three relaxed payload words plus a
/// sequence stamp written last with release ordering. A reader that sees
/// `stamp == seq + 1` for the sequence it expects knows the payload
/// stores of exactly that record happened-before; anything else is a
/// torn or not-yet-written slot and is skipped.
struct Slot {
    /// `kind | job << 8` (job ids clamp at u32::MAX >> 8 in practice).
    meta: AtomicU64,
    t0_us: AtomicU64,
    t1_us: AtomicU64,
    stamp: AtomicU64,
}

impl Slot {
    const fn empty() -> Slot {
        Slot {
            meta: AtomicU64::new(0),
            t0_us: AtomicU64::new(0),
            t1_us: AtomicU64::new(0),
            stamp: AtomicU64::new(0),
        }
    }
}

/// Fixed-capacity multi-producer span ring. Producers never block and
/// never allocate; on overflow the oldest spans are overwritten (the
/// trace keeps the most recent window, counters keep exact totals).
pub struct SpanRing {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
}

impl SpanRing {
    fn new(cap: usize) -> SpanRing {
        let cap = cap.next_power_of_two().max(2);
        SpanRing {
            slots: (0..cap).map(|_| Slot::empty()).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    /// Total spans ever recorded (≥ retained when the ring has wrapped).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Record one span. Wait-free: one `fetch_add` + four stores.
    pub fn record(&self, kind: SpanKind, job: u32, t0_us: u64, t1_us: u64) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq as usize) & (self.slots.len() - 1)];
        slot.meta.store(kind as u64 | ((job as u64) << 8), Ordering::Relaxed);
        slot.t0_us.store(t0_us, Ordering::Relaxed);
        slot.t1_us.store(t1_us, Ordering::Relaxed);
        // stamp = seq + 1 so "never written" (0) is unambiguous
        slot.stamp.store(seq + 1, Ordering::Release);
    }

    /// Read the retained window into `out` (oldest first), skipping
    /// slots torn by a concurrent wrap. Allocates only in `out`.
    pub fn drain_into(&self, track: u32, out: &mut Vec<Span>) {
        let total = self.cursor.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let first = total.saturating_sub(cap);
        for seq in first..total {
            let slot = &self.slots[(seq as usize) & (self.slots.len() - 1)];
            if slot.stamp.load(Ordering::Acquire) != seq + 1 {
                continue; // torn: overwritten (or mid-write) since we read the cursor
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let t0_us = slot.t0_us.load(Ordering::Relaxed);
            let t1_us = slot.t1_us.load(Ordering::Relaxed);
            if slot.stamp.load(Ordering::Acquire) != seq + 1 {
                continue; // overwritten while we were reading the payload
            }
            let Some(kind) = SpanKind::from_u8((meta & 0xff) as u8) else { continue };
            out.push(Span { kind, job: (meta >> 8) as u32, track, t0_us, t1_us });
        }
    }
}

/// Monotonic cumulative counters, all bumped with single relaxed
/// `fetch_add`s from the serving hot paths.
#[derive(Default)]
pub struct Counters {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub retries: AtomicU64,
    pub preemptions: AtomicU64,
    pub respawns: AtomicU64,
    pub faults_panic: AtomicU64,
    pub faults_timeout: AtomicU64,
    pub faults_divergence: AtomicU64,
}

impl Counters {
    pub fn bump(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// The per-process telemetry hub: one span ring per shard plus a control
/// track, per-shard busy-time accumulators, and the counter block.
/// Everything is preallocated in [`Telemetry::new`]; recording is
/// allocation-free.
pub struct Telemetry {
    /// Monotonic epoch all span timestamps are relative to.
    base: Instant,
    shards: usize,
    /// `shards + 1` rings; the last is the control (admission) track.
    rings: Box<[SpanRing]>,
    /// Per-shard busy time, microseconds (kernel time inside chunks).
    busy_us: Box<[AtomicU64]>,
    pub counters: Counters,
}

impl Telemetry {
    pub fn new(shards: usize) -> Telemetry {
        let shards = shards.max(1);
        Telemetry {
            base: Instant::now(),
            shards,
            rings: (0..shards + 1).map(|_| SpanRing::new(RING_SPANS)).collect(),
            busy_us: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            counters: Counters::default(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Track index of the admission/control ring.
    pub fn control_track(&self) -> usize {
        self.shards
    }

    /// Microseconds since the telemetry epoch.
    pub fn now_us(&self) -> u64 {
        self.base.elapsed().as_micros() as u64
    }

    fn ring(&self, track: usize) -> &SpanRing {
        &self.rings[track.min(self.shards)]
    }

    /// Record a duration span `[t0_us, now]` on a track.
    pub fn span_since(&self, track: usize, kind: SpanKind, job: usize, t0_us: u64) {
        let t1 = self.now_us();
        self.ring(track).record(kind, job as u32, t0_us.min(t1), t1);
    }

    /// Record a zero-duration instant mark on a track.
    pub fn instant(&self, track: usize, kind: SpanKind, job: usize) {
        let t = self.now_us();
        self.ring(track).record(kind, job as u32, t, t);
    }

    /// Accumulate kernel busy time on a shard.
    pub fn add_busy(&self, shard: usize, seconds: f64) {
        if seconds > 0.0 && shard < self.busy_us.len() {
            self.busy_us[shard].fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        }
    }

    pub fn busy_s(&self, shard: usize) -> f64 {
        self.busy_us.get(shard).map_or(0.0, |b| b.load(Ordering::Relaxed) as f64 * 1e-6)
    }

    /// Seconds since the telemetry epoch (the busy-fraction denominator).
    pub fn uptime_s(&self) -> f64 {
        self.base.elapsed().as_secs_f64()
    }

    /// Total spans recorded across every track (wrapped ones included).
    pub fn spans_recorded(&self) -> u64 {
        self.rings.iter().map(|r| r.recorded()).sum()
    }

    /// Decode every track's retained window, oldest-first per track.
    pub fn snapshot_spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for (i, ring) in self.rings.iter().enumerate() {
            ring.drain_into(i as u32, &mut out);
        }
        out.sort_by_key(|s| (s.track, s.t0_us, s.t1_us));
        out
    }

    /// Serialize the retained spans as Chrome trace-event JSON.
    pub fn write_chrome_trace(&self, path: &Path) -> Result<()> {
        let spans = self.snapshot_spans();
        let mut events: Vec<Json> = Vec::with_capacity(spans.len() + self.rings.len());
        for track in 0..self.rings.len() {
            let name = if track == self.shards {
                "control".to_string()
            } else {
                format!("shard {track}")
            };
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(track as f64)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
        }
        for s in &spans {
            let base = |ph: &str| {
                vec![
                    ("name", Json::str(s.kind.name())),
                    ("cat", Json::str("stencilax")),
                    ("ph", Json::str(ph)),
                    ("ts", Json::num(s.t0_us as f64)),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(s.track as f64)),
                    ("args", Json::obj(vec![("job", Json::num(s.job as f64))])),
                ]
            };
            if s.kind.is_instant() {
                let mut ev = base("i");
                ev.push(("s", Json::str("t")));
                events.push(Json::obj(ev));
            } else if s.kind.is_async() {
                // async begin/end pair scoped by job id: overlapping
                // waits render as separate async rows, not stack events
                let mut b = base("b");
                b.push(("id", Json::num(s.job as f64)));
                events.push(Json::obj(b));
                let mut e = base("e");
                e.push(("id", Json::num(s.job as f64)));
                e[3] = ("ts", Json::num(s.t1_us as f64));
                events.push(Json::obj(e));
            } else {
                let mut ev = base("X");
                ev.push(("dur", Json::num(s.t1_us.saturating_sub(s.t0_us) as f64)));
                events.push(Json::obj(ev));
            }
        }
        let doc = Json::obj(vec![
            ("traceEvents", Json::arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("schema", Json::str(TRACE_SCHEMA)),
                    ("shards", Json::num(self.shards as f64)),
                ]),
            ),
        ]);
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
        std::fs::write(path, doc.to_string_pretty())
            .with_context(|| format!("writing trace {path:?}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_drains_in_order() {
        let ring = SpanRing::new(8);
        ring.record(SpanKind::Chunk, 1, 10, 20);
        ring.record(SpanKind::Probe, 1, 20, 22);
        ring.record(SpanKind::Digest, 2, 30, 31);
        let mut out = Vec::new();
        ring.drain_into(0, &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].kind, SpanKind::Chunk);
        assert_eq!(out[0].t0_us, 10);
        assert_eq!(out[1].kind, SpanKind::Probe);
        assert_eq!(out[2].job, 2);
        assert_eq!(ring.recorded(), 3);
    }

    #[test]
    fn ring_wrap_keeps_most_recent_window() {
        let ring = SpanRing::new(4);
        for i in 0..10u64 {
            ring.record(SpanKind::Chunk, i as u32, i, i + 1);
        }
        let mut out = Vec::new();
        ring.drain_into(0, &mut out);
        assert_eq!(out.len(), 4, "retained window is the capacity");
        assert_eq!(out[0].job, 6, "oldest retained is total - cap");
        assert_eq!(out[3].job, 9);
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn concurrent_producers_never_corrupt_kinds() {
        let ring = std::sync::Arc::new(SpanRing::new(64));
        let mut handles = Vec::new();
        for t in 0..4 {
            let r = ring.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5000u32 {
                    r.record(SpanKind::Chunk, t * 10_000 + i, i as u64, i as u64 + 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut out = Vec::new();
        ring.drain_into(0, &mut out);
        assert!(out.len() <= 64);
        for s in &out {
            assert_eq!(s.kind, SpanKind::Chunk);
            assert_eq!(s.t1_us, s.t0_us + 1);
        }
        assert_eq!(ring.recorded(), 20_000);
    }

    #[test]
    fn telemetry_tracks_and_busy_accounting() {
        let tel = Telemetry::new(2);
        assert_eq!(tel.shards(), 2);
        assert_eq!(tel.control_track(), 2);
        tel.add_busy(0, 0.5);
        tel.add_busy(0, 0.25);
        tel.add_busy(9, 1.0); // out of range: ignored, not a panic
        assert!((tel.busy_s(0) - 0.75).abs() < 1e-6);
        assert_eq!(tel.busy_s(1), 0.0);
        let t0 = tel.now_us();
        tel.span_since(1, SpanKind::Chunk, 7, t0);
        tel.instant(0, SpanKind::Preempt, 3);
        tel.span_since(tel.control_track(), SpanKind::Admit, 7, t0);
        let spans = tel.snapshot_spans();
        assert_eq!(spans.len(), 3);
        assert!(spans.iter().any(|s| s.track == 2 && s.kind == SpanKind::Admit));
        assert_eq!(tel.spans_recorded(), 3);
    }

    #[test]
    fn chrome_trace_is_parseable_and_schema_tagged() {
        let tel = Telemetry::new(2);
        let t0 = tel.now_us();
        tel.span_since(0, SpanKind::Chunk, 1, t0);
        tel.span_since(0, SpanKind::QueueWait, 1, t0);
        tel.instant(1, SpanKind::Fault, 2);
        let path = std::env::temp_dir().join("stencilax_trace_unit.json");
        tel.write_chrome_trace(&path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.req_arr("traceEvents").unwrap();
        // 3 thread_name metas + 1 X + 1 async pair (b+e) + 1 instant
        assert_eq!(events.len(), 3 + 1 + 2 + 1);
        assert_eq!(
            doc.req("otherData").unwrap().req_str("schema").unwrap(),
            TRACE_SCHEMA
        );
        let phases: Vec<&str> =
            events.iter().map(|e| e.req_str("ph").unwrap()).collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 1);
        assert_eq!(phases.iter().filter(|p| **p == "b").count(), 1);
        assert_eq!(phases.iter().filter(|p| **p == "e").count(), 1);
        assert_eq!(phases.iter().filter(|p| **p == "i").count(), 1);
        for e in events {
            assert!(e.req_f64("pid").is_ok() || e.req_u64("pid").is_ok());
            assert!(e.get("tid").is_some() && e.get("ts").is_some() || e.req_str("ph").unwrap() == "M");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn counters_bump_relaxed() {
        let c = Counters::default();
        Counters::bump(&c.retries);
        Counters::bump(&c.retries);
        Counters::bump(&c.preemptions);
        assert_eq!(c.retries.load(Ordering::Relaxed), 2);
        assert_eq!(c.preemptions.load(Ordering::Relaxed), 1);
        assert_eq!(c.completed.load(Ordering::Relaxed), 0);
    }
}
