//! The steady-state time loop must be allocation-free after warmup
//! (ISSUE 2 acceptance): a counting allocator wraps the system allocator
//! and pins zero heap allocations per step for double-buffered diffusion3d
//! and the fused MHD stepper.
//!
//! The measurement runs serial (`STENCILAX_THREADS=1`, set before any
//! engine call): under work stealing the *set* of pool threads touching a
//! given step is nondeterministic, so a per-thread workspace could grow
//! during the measured window without any per-step allocation existing.
//! The serial path exercises exactly the same kernels and buffers — the
//! parallel dispatch itself is allocation-free by construction
//! (util/par.rs pool: borrowed job slot, atomic cursor, parked workers).
//! Everything lives in one #[test] so the env var is set once, before any
//! other engine activity in this process.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use stencilax::stencil::diffusion::Diffusion;
use stencilax::stencil::exec::DoubleBuffer;
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::stencil::mhd::{MhdParams, MhdState, MhdStepper};

struct Counting;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_stepping_is_allocation_free() {
    std::env::set_var("STENCILAX_THREADS", "1");

    // ---- diffusion3d, double-buffered ----------------------------------
    let d = Diffusion::new(3, 1.0, 1.0, Boundary::Periodic);
    let g = Grid::from_fn(&[24, 24, 24], 3, |i, j, k| ((i * 7 + j * 5 + k * 3) % 11) as f64);
    let mut field = DoubleBuffer::new(g);
    let dt = d.stable_dt(3);
    for _ in 0..3 {
        d.step_buffered(&mut field, 3, dt); // warmup: workspace growth
    }
    let before = allocs();
    for _ in 0..5 {
        d.step_buffered(&mut field, 3, dt);
    }
    let diffusion_allocs = allocs() - before;

    // ---- fused MHD stepper ---------------------------------------------
    let n = 16;
    let par = MhdParams { dx: 2.0 * std::f64::consts::PI / n as f64, ..Default::default() };
    let mut st = MhdState::from_fn(n, n, n, 3, |f, i, j, k| {
        1e-3 * (((f * 31 + i * 7 + j * 5 + k * 3) % 13) as f64 - 6.0)
    });
    let mut stepper = MhdStepper::new(par, 3, n, n, n);
    let dt = 1e-4;
    for _ in 0..2 {
        stepper.step(&mut st, dt); // warmup: MHD workspace is bigger
    }
    let before = allocs();
    for _ in 0..4 {
        stepper.step(&mut st, dt);
    }
    let mhd_allocs = allocs() - before;

    assert!(st.max_abs().is_finite(), "integration blew up");
    assert_eq!(diffusion_allocs, 0, "diffusion3d steady-state loop allocated");
    assert_eq!(mhd_allocs, 0, "fused MHD steady-state loop allocated");
}
