//! Wire-protocol and parity regressions for the serving daemon
//! (ISSUE 5): malformed/oversized/partial NDJSON lines and unknown
//! message types must reject *per line* while the stream keeps serving;
//! completion events arrive out of order and must still aggregate; and
//! the acceptance pin — `daemon --stdio` and `serve --jobs` over the
//! same job set produce bit-identical per-session digests.

use std::collections::HashMap;

use stencilax::coordinator::daemon::{client, server, DaemonOpts, Event, MAX_LINE_BYTES};
use stencilax::coordinator::service::{self, JobSpec};
use stencilax::util::json::Json;

fn job(workload: &str, shape: &[usize], steps: usize) -> JobSpec {
    JobSpec { workload: workload.into(), shape: shape.to_vec(), steps, ..JobSpec::default() }
}

fn opts() -> DaemonOpts {
    DaemonOpts { shards: 2, queue_cap: 8, ..DaemonOpts::default() }
}

/// Parse every emitted line back through the protocol.
fn parse_events(lines: &[String]) -> Vec<Event> {
    lines
        .iter()
        .map(|l| Event::parse_line(l).unwrap_or_else(|e| panic!("bad event line {l:?}: {e:#}")))
        .collect()
}

#[test]
fn daemon_stdio_and_batch_serve_produce_identical_digests() {
    let jobs = vec![
        job("conv1d-r3", &[1024], 2),
        job("diffusion1d", &[512], 3),
        job("diffusion2d", &[24, 24], 3),
        job("mhd", &[8, 8, 8], 2),
    ];
    let script: String = jobs.iter().map(|j| j.to_json().to_string_compact() + "\n").collect();
    // EOF after the last job line is the implicit drain
    let (daemon_report, lines) = server::serve_script(&script, &opts()).unwrap();
    let batch_report = service::run_jobs(&jobs, 2, None, true).unwrap();

    assert_eq!(daemon_report.results.len(), jobs.len());
    assert_eq!(batch_report.results.len(), jobs.len());
    assert!(daemon_report.rejected.is_empty() && batch_report.rejected.is_empty());
    for (d, b) in daemon_report.results.iter().zip(&batch_report.results) {
        assert_eq!(d.id, b.id);
        assert_eq!(d.workload, b.workload);
        assert_eq!(
            d.digest_bits, b.digest_bits,
            "daemon and batch digests must be bit-identical for job {} ({})",
            d.id, d.workload
        );
    }

    // the event stream is well-formed: per job, accepted -> started ->
    // done (whatever the cross-job interleaving), then one final report
    let events = parse_events(&lines);
    let mut stage: HashMap<usize, u32> = HashMap::new();
    for ev in &events {
        match ev {
            Event::Accepted { id, .. } => {
                assert_eq!(stage.insert(*id, 1), None, "duplicate accepted for {id}");
            }
            Event::Started { id, shard, queue_wait_s } => {
                assert_eq!(stage.insert(*id, 2), Some(1), "started before accepted for {id}");
                assert!(*shard < daemon_report.shards);
                assert!(queue_wait_s.is_finite() && *queue_wait_s >= 0.0);
            }
            Event::Stats(_) | Event::Metrics(_) => {}
            Event::Done(r) => {
                assert_eq!(stage.insert(r.id, 3), Some(2), "done before started for {}", r.id);
                assert!(r.latency_s > 0.0);
            }
            Event::Rejected { id, error, .. } => panic!("unexpected rejection of {id}: {error}"),
            Event::Failed(f) => panic!("unexpected failure of {}: {}", f.id, f.error),
            Event::Report(_) => {}
        }
    }
    assert!(stage.values().all(|&s| s == 3), "every job must reach done: {stage:?}");
    match events.last() {
        Some(Event::Report(j)) => {
            assert_eq!(j.req_str("schema").unwrap(), "stencilax-serve/1");
            assert_eq!(j.req_u64("jobs").unwrap() as usize, jobs.len());
            assert_eq!(j.req_arr("sessions").unwrap().len(), jobs.len());
        }
        other => panic!("stream must end with the aggregate report, got {other:?}"),
    }
}

#[test]
fn bad_lines_reject_per_line_while_the_stream_keeps_serving() {
    // ids are assigned per submission attempt, in line order:
    //   0 valid, 1 malformed JSON, 2 unknown type, 3 oversized,
    //   4 inadmissible job, 5 valid, 6 partial line at EOF (no newline)
    let mut script = String::new();
    script.push_str(&(job("diffusion2d", &[16, 16], 2).to_json().to_string_compact() + "\n"));
    script.push_str("{\"workload\": \"diffu\n"); // malformed
    script.push_str("{\"type\":\"restart\"}\n"); // unknown message type
    let pad = "x".repeat(MAX_LINE_BYTES);
    script.push_str(&format!("{{\"pad\":\"{pad}\"}}\n")); // oversized
    // bad shape: non-cubic MHD box fails admission, not parsing
    script.push_str(&(job("mhd", &[8, 8, 12], 1).to_json().to_string_compact() + "\n"));
    script.push_str(&(job("diffusion1d", &[256], 2).to_json().to_string_compact() + "\n"));
    script.push_str("{\"workload\":\"diffusion2d\",\"shape\":[16,"); // partial, truncated at EOF

    let (report, lines) = server::serve_script(&script, &opts()).unwrap();
    assert_eq!(
        report.results.iter().map(|r| r.id).collect::<Vec<_>>(),
        vec![0, 5],
        "valid jobs around the bad lines must still run"
    );
    assert_eq!(report.rejected.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3, 4, 6]);
    let errors: HashMap<usize, String> =
        report.rejected.iter().map(|r| (r.id, r.error.clone())).collect();
    assert!(errors[&1].contains("malformed"), "{:?}", errors[&1]);
    assert!(errors[&2].contains("unknown message type"), "{:?}", errors[&2]);
    assert!(errors[&3].contains("exceeds"), "{:?}", errors[&3]);
    assert!(errors[&4].contains("cannot run at shape"), "{:?}", errors[&4]);
    assert!(errors[&6].contains("malformed"), "{:?}", errors[&6]);

    // every rejection was also streamed as an event
    let events = parse_events(&lines);
    let rejected_ids: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Rejected { id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    assert_eq!(rejected_ids, vec![1, 2, 3, 4, 6]);
    // and the final report's rejected array matches
    match events.last() {
        Some(Event::Report(j)) => {
            assert_eq!(j.req_arr("rejected").unwrap().len(), 5);
            assert_eq!(j.req_u64("jobs").unwrap(), 7);
        }
        other => panic!("expected final report, got {other:?}"),
    }
}

#[test]
fn explicit_drain_and_shutdown_controls() {
    // drain after submissions: everything queued still completes
    let mut script = String::new();
    script.push_str(&(job("diffusion2d", &[16, 16], 2).to_json().to_string_compact() + "\n"));
    script.push_str("{\"type\":\"drain\"}\n");
    script.push_str("this line is never read\n");
    let (report, lines) = server::serve_script(&script, &opts()).unwrap();
    assert_eq!(report.results.len(), 1);
    assert!(report.rejected.is_empty(), "{:?}", report.rejected);
    assert!(matches!(parse_events(&lines).last(), Some(Event::Report(_))));

    // shutdown as the first line: no sessions, immediate report
    let (report, lines) = server::serve_script("{\"type\":\"shutdown\"}\n", &opts()).unwrap();
    assert!(report.results.is_empty());
    assert!(report.rejected.is_empty());
    let events = parse_events(&lines);
    assert_eq!(events.len(), 1, "only the report: {lines:?}");
    assert!(matches!(events.last(), Some(Event::Report(_))));
}

#[test]
fn daemon_over_unix_socket_serves_submit_client_end_to_end() {
    let socket =
        std::env::temp_dir().join(format!("stencilax_daemon_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&socket);
    let server_path = socket.clone();
    let server = std::thread::spawn(move || server::serve_socket(&server_path, &opts()));

    let file = Json::obj(vec![
        ("schema", Json::str("stencilax-jobs/1")),
        (
            "jobs",
            Json::arr(vec![
                job("diffusion2d", &[16, 16], 2).to_json(),
                job("no-such-workload", &[8], 1).to_json(),
                job("diffusion1d", &[256], 2).to_json(),
            ]),
        ),
    ]);
    let lines = client::job_lines(&file).unwrap();
    let summary = client::submit_lines(
        &socket,
        &lines,
        true,
        std::time::Duration::from_secs(5),
        |_, _| {},
    )
    .unwrap();

    assert_eq!(summary.submitted, 3);
    assert_eq!(summary.outcome.done.len(), 2);
    assert_eq!(summary.outcome.rejected.len(), 1);
    assert!(summary.outcome.rejected[0].1.contains("unknown workload"));
    let done = summary.outcome.done_by_id();
    assert_eq!(done.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    let report_event = summary.outcome.report.as_ref().expect("shutdown returns the report");
    assert_eq!(report_event.req_u64("jobs").unwrap(), 3);

    // the server side agrees with what the client saw, digest included
    let report = server.join().unwrap().unwrap();
    assert_eq!(report.results.len(), 2);
    assert_eq!(report.rejected.len(), 1);
    for (srv, cli) in report.results.iter().zip(done) {
        assert_eq!(srv.id, cli.id);
        assert_eq!(srv.digest_bits, cli.digest_bits, "wire digest must match server digest");
    }
    assert!(!socket.exists(), "daemon must remove its socket file on exit");
}
