//! Fault-isolation regressions for the serving daemon (ISSUE 7): an
//! injected panic, stall, or NaN must become a per-job `failed` event —
//! never a dead shard or a crashed daemon — with retryable classes
//! recovering to the *fault-free golden digest* and unretryable ones
//! failing terminally while every co-scheduled job is untouched; a
//! transport read error must drain the stream instead of killing the
//! process; and malformed `timeout_s`/`max_retries` knobs must reject
//! per line at admission.
//!
//! Every run here is byte-reproducible: the [`FaultPlan`] grammar pins
//! faults to job ids, faults fire only on a session's first attempt, and
//! the golden twin runs the identical script with injection disabled.

use stencilax::coordinator::daemon::{server, DaemonOpts, Event, FailureKind};
use stencilax::coordinator::plans::{host_fingerprint, PlanCache, PlanEntry};
use stencilax::coordinator::service::{FailureHistogram, JobSpec, ServiceReport};
use stencilax::coordinator::FaultPlan;
use stencilax::stencil::plan::{LaunchPlan, MAX_DEPTH};

fn job(workload: &str, shape: &[usize], steps: usize) -> JobSpec {
    JobSpec { workload: workload.into(), shape: shape.to_vec(), steps, ..JobSpec::default() }
}

fn script_of(jobs: &[JobSpec]) -> String {
    jobs.iter().map(|j| j.to_json().to_string_compact() + "\n").collect()
}

fn opts_with(faults: Option<FaultPlan>) -> DaemonOpts {
    DaemonOpts { shards: 2, queue_cap: 16, faults, ..DaemonOpts::default() }
}

fn run(jobs: &[JobSpec], faults: Option<&str>) -> (ServiceReport, Vec<Event>) {
    let faults = faults.map(|spec| FaultPlan::parse(spec).unwrap());
    let (report, lines) = server::serve_script(&script_of(jobs), &opts_with(faults)).unwrap();
    let events = lines
        .iter()
        .map(|l| Event::parse_line(l).unwrap_or_else(|e| panic!("bad event line {l:?}: {e:#}")))
        .collect();
    (report, events)
}

#[test]
fn injected_panic_retries_to_the_fault_free_golden_digest() {
    let jobs = vec![
        job("conv1d-r3", &[1024], 4),
        job("diffusion2d", &[16, 16], 4), // panic target
        job("diffusion1d", &[256], 4),
    ];
    let (golden, _) = run(&jobs, None);
    assert_eq!(golden.results.len(), 3, "golden run must be clean: {:?}", golden.failed);
    assert_eq!(golden.failure_histogram, FailureHistogram::default());

    let (chaos, events) = run(&jobs, Some("panic@1"));
    // the panic was contained, retried, and recovered: every job done
    assert_eq!(chaos.results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    assert!(chaos.failed.is_empty(), "recovered jobs are not terminal: {:?}", chaos.failed);
    assert_eq!(chaos.failure_histogram.panic, 1, "the recovered attempt still counts");
    assert_eq!(chaos.failure_histogram.total(), 1);
    for r in &chaos.results {
        assert_eq!(
            r.digest_bits, golden.results[r.id].digest_bits,
            "job {} digest must be bit-identical to the fault-free run",
            r.id
        );
    }
    assert!(chaos.results[1].retries >= 1, "the faulted job must record its rerun");
    assert_eq!(chaos.results[0].retries, 0);
    assert_eq!(chaos.results[2].retries, 0);

    // the transient failure was streamed, flagged as a rerun, and placed
    let transients: Vec<_> = events
        .iter()
        .filter_map(|e| match e {
            Event::Failed(f) => Some(f),
            _ => None,
        })
        .collect();
    assert_eq!(transients.len(), 1);
    let f = transients[0];
    assert_eq!((f.id, f.kind, f.will_retry), (1, FailureKind::Panic, true));
    assert_eq!(f.step, 2, "panic@1 over 4 steps fires mid-session");
    assert!(f.error.contains("injected fault"), "{:?}", f.error);
}

#[test]
fn timeout_and_divergence_fail_terminally_without_collateral() {
    let mut stall_target = job("diffusion2d", &[16, 16], 4);
    stall_target.timeout_s = Some(0.05);
    stall_target.max_retries = Some(0);
    let jobs = vec![
        job("diffusion2d", &[16, 16], 4),
        stall_target, // id 1: stall blows the watchdog, no retries left
        job("mhd", &[8, 8, 8], 4), // id 2: NaN poison -> divergence, unretryable
        job("diffusion1d", &[256], 4), // id 3: arrives behind the faulted jobs
    ];
    let (golden, _) = run(&jobs, None);
    assert_eq!(golden.results.len(), 4, "golden run must be clean: {:?}", golden.failed);

    let (chaos, events) = run(&jobs, Some("stall@1,nan@2,stall_ms=100"));
    assert_eq!(
        chaos.results.iter().map(|r| r.id).collect::<Vec<_>>(),
        vec![0, 3],
        "healthy jobs around the failures must still complete"
    );
    for r in &chaos.results {
        assert_eq!(
            r.digest_bits, golden.results[r.id].digest_bits,
            "job {} must be untouched by its neighbors' faults",
            r.id
        );
        assert_eq!(r.retries, 0);
    }
    assert_eq!(chaos.failed.iter().map(|f| f.id).collect::<Vec<_>>(), vec![1, 2]);
    assert_eq!(chaos.failed[0].kind, FailureKind::Timeout);
    assert_eq!(chaos.failed[1].kind, FailureKind::Divergence);
    assert_eq!(chaos.failed[1].step, 2, "divergence reports the step of first detection");
    assert!(chaos.failed.iter().all(|f| !f.will_retry));
    let h = &chaos.failure_histogram;
    assert_eq!((h.panic, h.timeout, h.divergence, h.transport), (0, 1, 1, 0));

    // the final report event carries the taxonomy, and it roundtrips
    match events.last() {
        Some(Event::Report(j)) => {
            assert_eq!(j.req_u64("jobs").unwrap(), 4);
            assert_eq!(j.req_arr("failed").unwrap().len(), 2);
            let wire = FailureHistogram::from_json(j.req("failure_histogram").unwrap()).unwrap();
            assert_eq!(&wire, h);
        }
        other => panic!("stream must end with the aggregate report, got {other:?}"),
    }
}

/// A plan cache whose diffusion2d 16x16 entry carries the maximum
/// temporal depth, inserted at every plausible per-shard thread budget so
/// the host-scoped lookup hits regardless of how the daemon splits its
/// cores across shards.
fn depth_tuned_cache() -> PlanCache {
    let mut cache = PlanCache::new();
    for threads in 1..=64 {
        cache.insert(PlanEntry {
            workload: "diffusion2d".into(),
            shape: vec![16, 16],
            threads,
            host: host_fingerprint(),
            plan: LaunchPlan { depth: MAX_DEPTH, ..LaunchPlan::default_for(&[16, 16], threads) },
            tuned_melem_per_s: 1.0,
            default_melem_per_s: 1.0,
        });
    }
    cache
}

#[test]
fn depth_chunked_sessions_honor_the_watchdog_and_keep_digest_parity() {
    // ISSUE 9 satellite: serving advances depth-tuned sessions one
    // multi-step chunk per step_checked call, so the watchdog's busy-time
    // accounting must charge each chunk for the steps it actually
    // advanced. If a chunk were charged as one step (or judged against a
    // one-step budget), honest depth-4 work would either dodge or trip
    // the timeout — both pinned here, against the same daemon path the
    // chaos suite exercises.
    let jobs = vec![
        job("diffusion2d", &[16, 16], 2 * MAX_DEPTH + 1), // partial tail chunk
        job("diffusion2d", &[16, 16], 4),
        job("diffusion1d", &[256], 4), // no tuned entry: classic stepping
    ];
    let (golden, _) = run(&jobs, None);
    assert_eq!(golden.results.len(), 3, "golden run must be clean: {:?}", golden.failed);

    // fault-free depth-4 serving: nothing times out (honest chunk work
    // fits the whole-attempt budget) and every digest is bit-identical
    // to the depth-1 golden run
    let opts = DaemonOpts { plans: Some(depth_tuned_cache()), ..opts_with(None) };
    let (deep, _) = server::serve_script(&script_of(&jobs), &opts).unwrap();
    assert_eq!(deep.results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    assert!(deep.failed.is_empty(), "depth-chunked runs must not trip the watchdog: {:?}", deep.failed);
    assert_eq!(deep.failure_histogram, FailureHistogram::default());
    assert!(
        deep.results.iter().take(2).all(|r| r.tuned),
        "diffusion2d jobs must run under the depth-tuned cache entry"
    );
    for r in &deep.results {
        assert_eq!(
            r.digest_bits, golden.results[r.id].digest_bits,
            "job {} at depth {MAX_DEPTH} must match the depth-1 digest bit for bit",
            r.id
        );
    }

    // an injected stall inside a depth-chunked session still blows the
    // per-job watchdog — chunking must not launder a hang into "busy"
    let mut stall_target = job("diffusion2d", &[16, 16], 4);
    stall_target.timeout_s = Some(0.05);
    stall_target.max_retries = Some(0);
    let jobs = vec![job("diffusion2d", &[16, 16], 4), stall_target];
    let faults = Some(FaultPlan::parse("stall@1,stall_ms=100").unwrap());
    let opts = DaemonOpts { plans: Some(depth_tuned_cache()), ..opts_with(faults) };
    let (chaos, _) = server::serve_script(&script_of(&jobs), &opts).unwrap();
    assert_eq!(chaos.results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    assert_eq!(chaos.failed.iter().map(|f| (f.id, f.kind)).collect::<Vec<_>>(), vec![
        (1, FailureKind::Timeout)
    ]);
    assert_eq!(
        chaos.results[0].digest_bits, golden.results[0].digest_bits,
        "the healthy depth-chunked neighbor must be untouched"
    );
}

#[test]
fn transport_read_error_drains_the_stream_instead_of_crashing() {
    let jobs = vec![job("diffusion2d", &[16, 16], 2), job("diffusion1d", &[256], 2)];
    // line 0 is read cleanly; the read of line 1 errors, so job 1 is
    // never admitted and the daemon drains what it has
    let (report, events) = run(&jobs, Some("transport@1"));
    assert_eq!(report.results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    assert!(report.rejected.is_empty(), "{:?}", report.rejected);
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    assert_eq!(report.transport_errors.len(), 1);
    assert_eq!(report.transport_errors[0].kind, "read");
    assert!(report.transport_errors[0].error.contains("injected fault"));
    assert_eq!(report.failure_histogram.transport, 1, "transport errors land in the histogram");
    assert!(matches!(events.last(), Some(Event::Report(_))), "error-triggered drain still reports");
}

#[test]
fn invalid_timeout_and_retry_knobs_reject_per_line() {
    // ids follow line order: 0 valid, 1-4 malformed knobs, 5 valid
    let valid = job("diffusion2d", &[16, 16], 2).to_json().to_string_compact();
    let with_knob = |knob: &str| {
        format!("{{\"workload\":\"diffusion2d\",\"shape\":[16,16],\"steps\":2,{knob}}}\n")
    };
    let mut script = String::new();
    script.push_str(&(valid.clone() + "\n"));
    script.push_str(&with_knob("\"timeout_s\":-1"));
    script.push_str(&with_knob("\"timeout_s\":\"soon\""));
    script.push_str(&with_knob("\"max_retries\":1.5"));
    script.push_str(&with_knob("\"max_retries\":-2"));
    script.push_str(&(valid + "\n"));

    let (report, _) = server::serve_script(&script, &opts_with(None)).unwrap();
    assert_eq!(
        report.results.iter().map(|r| r.id).collect::<Vec<_>>(),
        vec![0, 5],
        "valid jobs around the bad knobs must still run"
    );
    assert_eq!(report.rejected.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
    assert!(report.rejected[0].error.contains("timeout_s"), "{:?}", report.rejected[0]);
    assert!(report.rejected[1].error.contains("timeout_s"), "{:?}", report.rejected[1]);
    assert!(report.rejected[2].error.contains("max_retries"), "{:?}", report.rejected[2]);
    assert!(report.rejected[3].error.contains("max_retries"), "{:?}", report.rejected[3]);
    // both completions are the same spec: bit-identical results
    assert_eq!(report.results[0].digest_bits, report.results[1].digest_bits);
}
