//! Fused-vs-unfused parity for every registry workload (ISSUE 2): the
//! rebuilt execution layer (exec.rs row-blocked sweeps, step_into, the
//! fused MHD substep) must agree with straightforward bounds-checked
//! references across boundaries, radii 1-8, odd grid extents, and
//! `STENCILAX_THREADS` in {1, 4}.
//!
//! Thread counts are driven through the real env var so the whole dispatch
//! path (pool vs inline) is exercised; tests serialize on `ENV_LOCK`
//! because the variable is process-global.

use std::sync::Mutex;

use stencilax::stencil::central_weights;
use stencilax::stencil::conv;
use stencilax::stencil::diffusion::Diffusion;
use stencilax::stencil::exec;
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::stencil::mhd::{MhdParams, MhdState, MhdStepper};
use stencilax::util::prop::check;
use stencilax::util::rng::Rng;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under a pinned `STENCILAX_THREADS` (serialized process-wide).
fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("STENCILAX_THREADS", threads.to_string());
    let r = f();
    std::env::remove_var("STENCILAX_THREADS");
    r
}

const THREAD_COUNTS: [usize; 2] = [1, 4];

/// Naive bounds-checked diffusion step: per-point separable Laplacian over
/// `get()`, no blocking, no parallelism — the oracle the engine must match.
fn naive_diffusion_step(src: &Grid, dim: usize, radius: usize, alpha: f64, dx: f64, dt: f64) -> Grid {
    let c2 = central_weights(2, radius);
    let s = dt * alpha / (dx * dx);
    let (px, py, _) = src.padded();
    let data = src.data();
    let strides = [1usize, px, px * py];
    let mut out = Grid::new(src.nx, src.ny, src.nz, src.r);
    for k in 0..src.nz {
        for j in 0..src.ny {
            for i in 0..src.nx {
                let center = src.idx(i, j, k);
                let mut lap = 0.0;
                for axis in 0..dim {
                    for (t, &c) in c2.iter().enumerate() {
                        if c == 0.0 {
                            continue;
                        }
                        lap += c * data[center + t * strides[axis] - radius * strides[axis]];
                    }
                }
                out.set(i, j, k, data[center] + s * lap);
            }
        }
    }
    out
}

#[test]
fn diffusion_matches_naive_reference_all_radii_boundaries_threads() {
    for &threads in &THREAD_COUNTS {
        with_threads(threads, || {
            check(&format!("diffusion parity (threads={threads})"), 8, |rng| {
                let radius = 1 + rng.below(8); // radii 1..=8
                let dim = 1 + rng.below(3);
                // odd extents on purpose (uneven row blocks)
                let shape: Vec<usize> =
                    (0..dim).map(|_| 3 + 2 * rng.below(6) + 2 * radius).collect();
                let boundary = if rng.uniform() < 0.5 {
                    Boundary::Periodic
                } else {
                    Boundary::Fixed(rng.range(-1.0, 1.0))
                };
                let mut g = Grid::from_fn(&shape, radius, |_, _, _| rng.normal());
                let (alpha, dx) = (rng.range(0.2, 2.0), rng.range(0.3, 1.5));
                let d = Diffusion::new(radius, alpha, dx, boundary);
                let dt = d.stable_dt(dim);
                let got = d.step(&mut g, dim, dt); // fills g's ghosts in place
                let want = naive_diffusion_step(&g, dim, radius, alpha, dx, dt);
                let err = got.max_abs_diff(&want);
                stencilax::prop_assert!(
                    err <= 1e-12,
                    "radius={radius} dim={dim} shape={shape:?} err={err:.3e}"
                );
                Ok(())
            });
        });
    }
}

/// Naive dense cross-correlation via bounds-checked reads of padded data.
fn naive_xcorr_dense(input: &Grid, kernel: &[f64], kx: usize, ky: usize, kz: usize) -> Grid {
    let (rx, ry, rz) = (kx / 2, ky / 2, kz / 2);
    let r = input.r;
    let data = input.data();
    let mut out = Grid::new(input.nx, input.ny, input.nz, r);
    for k in 0..input.nz {
        for j in 0..input.ny {
            for i in 0..input.nx {
                let mut acc = 0.0;
                for dz in 0..kz {
                    for dy in 0..ky {
                        for dx in 0..kx {
                            let g = kernel[dx + kx * (dy + ky * dz)];
                            let pi = r + i - rx + dx;
                            let pj = r + j - ry + dy;
                            let pk = r + k - rz + dz;
                            acc += g * data[input.pidx(pi, pj, pk)];
                        }
                    }
                }
                out.set(i, j, k, acc);
            }
        }
    }
    out
}

#[test]
fn xcorr_dense_matches_naive_reference() {
    for &threads in &THREAD_COUNTS {
        with_threads(threads, || {
            check(&format!("xcorr_dense parity (threads={threads})"), 6, |rng| {
                let dim = 1 + rng.below(3);
                let radius = 1 + rng.below(if dim == 3 { 2 } else { 4 });
                let shape: Vec<usize> =
                    (0..dim).map(|_| 3 + 2 * rng.below(5) + 2 * radius).collect();
                let kn = 2 * radius + 1;
                let (kx, ky, kz) =
                    (kn, if dim >= 2 { kn } else { 1 }, if dim >= 3 { kn } else { 1 });
                let kernel = rng.normal_vec(kx * ky * kz);
                let mut g = Grid::from_fn(&shape, radius, |_, _, _| rng.normal());
                g.fill_ghosts(Boundary::Periodic);
                let got = conv::xcorr_dense(&g, &kernel, kx, ky, kz);
                let want = naive_xcorr_dense(&g, &kernel, kx, ky, kz);
                let err = got.max_abs_diff(&want);
                stencilax::prop_assert!(
                    err <= 1e-12 * (1.0 + want.max_abs()),
                    "dim={dim} radius={radius} shape={shape:?} err={err:.3e}"
                );
                Ok(())
            });
        });
    }
}

#[test]
fn xcorr1d_matches_naive_reference_radii_1_to_8() {
    for &threads in &THREAD_COUNTS {
        with_threads(threads, || {
            let mut rng = Rng::new(7 + threads as u64);
            for radius in 1..=8usize {
                // span several pool chunks and an odd tail
                let n = 3 * 8192 + 1021;
                let fpad = rng.normal_vec(n + 2 * radius);
                let taps = rng.normal_vec(2 * radius + 1);
                let got = conv::xcorr1d(&fpad, &taps);
                for (i, &v) in got.iter().enumerate() {
                    let want: f64 =
                        taps.iter().enumerate().map(|(t, &c)| c * fpad[i + t]).sum();
                    assert!(
                        (v - want).abs() <= 1e-12 * (1.0 + want.abs()),
                        "threads={threads} radius={radius} i={i}: {v} vs {want}"
                    );
                }
            }
        });
    }
}

#[test]
fn mhd_fused_substep_matches_reference_trajectories() {
    // fused path vs the retained unfused reference, across odd extents,
    // all three substeps, several full steps, both thread counts
    for &threads in &THREAD_COUNTS {
        with_threads(threads, || {
            for (nx, ny, nz) in [(9usize, 7usize, 5usize), (8, 8, 8)] {
                let par = MhdParams { dx: 0.37, zeta: 0.1, ..Default::default() };
                let mut rng = Rng::new(1234);
                let mut a = MhdState::from_fn(nx, ny, nz, 3, |_, _, _, _| 1e-2 * rng.normal());
                let mut b = a.clone();
                let mut sa = MhdStepper::new(par.clone(), 3, nx, ny, nz);
                let mut sb = MhdStepper::new(par, 3, nx, ny, nz);
                let dt = 1e-3;
                for step in 0..3 {
                    for l in 0..3 {
                        sa.substep(&mut a, dt, l);
                        sb.substep_reference(&mut b, dt, l);
                        let err = a
                            .fields
                            .iter()
                            .zip(&b.fields)
                            .map(|(x, y)| x.max_abs_diff(y))
                            .fold(0.0, f64::max);
                        assert!(
                            err <= 1e-12,
                            "threads={threads} box=({nx},{ny},{nz}) step={step} l={l}: err={err:.3e}"
                        );
                        let werr = sa
                            .w
                            .fields
                            .iter()
                            .zip(&sb.w.fields)
                            .map(|(x, y)| x.max_abs_diff(y))
                            .fold(0.0, f64::max);
                        assert!(werr <= 1e-12, "scratch register diverged: {werr:.3e}");
                    }
                }
            }
        });
    }
}

#[test]
fn registry_digests_agree_across_thread_counts() {
    // every registered workload's native reference evaluator must produce
    // the same digest under serial and 4-way execution (the engine's
    // decomposition must not change results)
    use stencilax::sim::workload::registry;
    let digests: Vec<Vec<f64>> = THREAD_COUNTS
        .iter()
        .map(|&threads| {
            with_threads(threads, || {
                registry().iter().map(|w| w.reference_digest(42)).collect()
            })
        })
        .collect();
    for (w, (a, b)) in registry().iter().zip(digests[0].iter().zip(&digests[1])) {
        assert!(
            (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
            "{}: digest {a} (1 thread) vs {b} (4 threads)",
            w.name()
        );
    }
}

// ---------------------------------------------------------------------------
// the 2-D parallelism hole (ISSUE 2 satellite): nz == 1 must decompose
// ---------------------------------------------------------------------------

#[test]
fn two_d_sweeps_are_distributed_across_threads() {
    // plan level: a 2-D interior (nz == 1) yields enough row blocks
    let threads = std::env::var("STENCILAX_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4usize);
    let (blocks, _) = exec::plan_blocks(4096, threads);
    assert!(blocks >= threads, "2-D rows not speedup-eligible: {blocks} blocks");

    // behaviour level: a (ny=256, nz=1) sweep actually runs on >= 2
    // threads. Work stealing means a single attempt can legitimately be
    // drained by the caller on a saturated machine, so retry a bounded
    // number of times — the decomposition is wrong only if *no* attempt
    // ever lands on a second thread.
    with_threads(4, || {
        use std::collections::HashSet;
        let mut g = Grid::new(32, 256, 1, 3);
        let mut n_threads = 0;
        for _attempt in 0..20 {
            let seen = Mutex::new(HashSet::new());
            exec::par_fill_rows(&mut g, |j, _k, row, _ws| {
                seen.lock().unwrap().insert(std::thread::current().id());
                // enough work per block that parked workers get to wake
                if j % 16 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                row.fill(j as f64);
            });
            n_threads = seen.lock().unwrap().len();
            if n_threads >= 2 {
                break;
            }
        }
        assert!(n_threads >= 2, "2-D sweep never left the calling thread");
        for j in 0..256 {
            assert_eq!(g.get(5, j, 0), j as f64);
        }
    });
}

#[test]
fn diffusion2d_results_identical_serial_vs_parallel() {
    // decomposition must not change the numbers: 4-thread result of the
    // 2-D stepper is bit-identical to the serial one
    let g0 = Grid::from_fn(&[129, 67], 3, |i, j, _| ((i * 13 + j * 7) % 17) as f64);
    let d = Diffusion::new(3, 1.0, 1.0, Boundary::Periodic);
    let dt = d.stable_dt(2);
    let serial = with_threads(1, || {
        let mut g = g0.clone();
        d.step(&mut g, 2, dt).interior_to_vec()
    });
    let parallel = with_threads(4, || {
        let mut g = g0.clone();
        d.step(&mut g, 2, dt).interior_to_vec()
    });
    assert_eq!(serial, parallel);
}
