//! Integration: coordinator machinery over the real artifact set — timing
//! harness, random-input generation, measured-figure tables, and the CLI
//! config plumbing. Skips cleanly when artifacts are absent.

use stencilax::config::Config;
use stencilax::coordinator::timing::{bench_artifact, random_inputs, time_artifact};
use stencilax::harness::measured;
use stencilax::runtime::{Executor, Manifest};
use stencilax::util::bench::Bencher;

fn executor() -> Option<Executor> {
    if cfg!(not(feature = "pjrt")) {
        // intentionally skipped: executing artifacts needs the XLA/PJRT
        // bindings, which the offline build does not carry (DESIGN.md §9)
        eprintln!("skipping: stencilax built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
}

fn quick_bencher() -> Bencher {
    Bencher {
        warmup: 1,
        min_iters: 3,
        max_iters: 5,
        budget: std::time::Duration::from_millis(500),
    }
}

#[test]
fn random_inputs_match_manifest_specs() {
    let Some(ex) = executor() else { return };
    for name in ["copy_n16384_f32", "xcorr1d_lib_r4_f64", "mhd32_hwc_sub0_f64"] {
        let entry = ex.manifest.get(name).unwrap().clone();
        let inputs = random_inputs(&ex, name, 9, 0.5).unwrap();
        assert_eq!(inputs.len(), entry.inputs.len());
        for (spec, val) in entry.inputs.iter().zip(&inputs) {
            assert_eq!(spec.shape, val.shape(), "{name}");
            assert_eq!(spec.dtype, val.dtype(), "{name}");
        }
        // scalar slots carry the requested value
        if let Some(pos) = entry.inputs.iter().position(|s| s.shape == [1]) {
            assert_eq!(inputs[pos].to_f64_vec()[0] as f32, 0.5f32);
        }
    }
}

#[test]
fn timing_harness_returns_sane_stats() {
    let Some(ex) = executor() else { return };
    let b = quick_bencher();
    let inputs = random_inputs(&ex, "copy_n16384_f64", 1, 0.0).unwrap();
    let stats = time_artifact(&ex, "copy_n16384_f64", &inputs, &b).unwrap();
    assert!(stats.iters >= 3);
    assert!(stats.min_s > 0.0 && stats.min_s <= stats.median_s);
    assert!(stats.median_s <= stats.max_s);
    assert!(stats.median_s < 1.0, "tiny copy must be fast, got {}", stats.median_s);
}

#[test]
fn bench_artifact_rejects_unknown_names() {
    let Some(ex) = executor() else { return };
    assert!(bench_artifact(&ex, "no_such_artifact", &quick_bencher(), 0.0).is_err());
}

#[test]
fn measured_bandwidth_produces_a_row_per_copy_artifact() {
    let Some(_) = executor() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.bench_iters = 3;
    cfg.bench_warmup = 1;
    cfg.bench_budget_s = 0.3;
    let out = measured::measured_bandwidth(&cfg).unwrap();
    let table = &out.tables[0];
    assert_eq!(table.rows.len(), 10, "5 sizes x 2 dtypes");
    for row in &table.rows {
        let gibs: f64 = row[3].parse().unwrap();
        assert!(gibs > 0.0);
    }
}

#[test]
fn executor_rejects_shape_mismatches() {
    let Some(ex) = executor() else { return };
    use stencilax::runtime::HostValue;
    // wrong shape
    let bad = ex.run("copy_n16384_f64", &[HostValue::f64(vec![0.0; 8], &[8])]);
    assert!(bad.is_err());
    // wrong dtype
    let bad = ex.run("copy_n16384_f64", &[HostValue::f32(vec![0.0; 16384], &[16384])]);
    assert!(bad.is_err());
    // wrong arity
    let bad = ex.run("copy_n16384_f64", &[]);
    assert!(bad.is_err());
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(ex) = executor() else { return };
    let inputs = random_inputs(&ex, "copy_n65536_f32", 3, 0.0).unwrap();
    ex.run("copy_n65536_f32", &inputs).unwrap();
    let after_first = *ex.compile_seconds.lock().unwrap();
    ex.run("copy_n65536_f32", &inputs).unwrap();
    let after_second = *ex.compile_seconds.lock().unwrap();
    assert_eq!(after_first, after_second, "second run must not recompile");
}
