//! Integration: the PJRT runtime executes AOT artifacts and the results
//! agree with the native Rust engine and the exported jnp oracles.
//!
//! Requires `make artifacts`; every test skips cleanly when the artifacts
//! directory is absent (e.g. a fresh checkout before the first build).

use stencilax::runtime::{DType, Executor, HostValue, Manifest};
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::stencil::mhd::{MhdParams, MhdState, MhdStepper, NFIELDS};
use stencilax::stencil::{conv, diffusion::Diffusion};
use stencilax::util::rng::Rng;

fn executor() -> Option<Executor> {
    if cfg!(not(feature = "pjrt")) {
        // intentionally skipped: executing artifacts needs the XLA/PJRT
        // bindings, which the offline build does not carry (DESIGN.md §9)
        eprintln!("skipping: stencilax built without the `pjrt` feature");
        return None;
    }
    let dir = manifest_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
}

fn manifest_dir() -> std::path::PathBuf {
    // tests run from the crate root
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn copy_artifact_is_identity() {
    let Some(ex) = executor() else { return };
    let n = 16384usize;
    let mut rng = Rng::new(1);
    let data = rng.normal_vec(n);
    let out = ex
        .run("copy_n16384_f64", &[HostValue::f64(data.clone(), &[n])])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].to_f64_vec(), data);
}

#[test]
fn xcorr_artifact_matches_native_engine() {
    let Some(ex) = executor() else { return };
    let (n, r) = (1usize << 20, 4usize);
    let mut rng = Rng::new(2);
    let fpad = rng.normal_vec(n + 2 * r);
    let taps = rng.normal_vec(2 * r + 1);
    let native = conv::xcorr1d(&fpad, &taps);
    for variant in ["hwc_baseline", "swc_pointwise", "hwc_elementwise"] {
        let name = format!("xcorr1d_{variant}_r{r}_f64");
        let out = ex
            .run(
                &name,
                &[
                    HostValue::f64(fpad.clone(), &[n + 2 * r]),
                    HostValue::f64(taps.clone(), &[2 * r + 1]),
                ],
            )
            .unwrap();
        let got = out[0].to_f64_vec();
        let err = got
            .iter()
            .zip(&native)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-12, "{name}: max err {err}");
    }
}

#[test]
fn diffusion_artifact_matches_native_engine() {
    let Some(ex) = executor() else { return };
    let (n, r) = (64usize, 3usize);
    let mut rng = Rng::new(3);
    let mut grid = Grid::new(n, n, n, r);
    grid.interior_from_slice(&rng.normal_vec(n * n * n));
    grid.fill_ghosts(Boundary::Periodic);

    let d = Diffusion::new(r, 1.0, 1.0, Boundary::Periodic);
    let dt = 1e-3;
    let native = d.step_prefilled(&grid, 3, dt);

    let s = d.kernel_scalar(dt);
    let out = ex
        .run(
            "diffusion3d_hwc_r3_f64",
            &[
                HostValue::f64(grid.padded_to_vec(), &[n + 2 * r, n + 2 * r, n + 2 * r]),
                HostValue::scalar(s, DType::F64),
            ],
        )
        .unwrap();
    let got = out[0].to_f64_vec();
    let want = native.interior_to_vec();
    let err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    assert!(err < 1e-12, "max err {err}");
}

#[test]
fn diffusion_swc_equals_hwc() {
    let Some(ex) = executor() else { return };
    let (n, r) = (64usize, 2usize);
    let mut rng = Rng::new(4);
    let shape = [n + 2 * r, n + 2 * r, n + 2 * r];
    let fpad = rng.normal_vec(shape.iter().product());
    let inputs = [HostValue::f64(fpad, &shape), HostValue::scalar(0.05, DType::F64)];
    let a = ex.run("diffusion3d_hwc_r2_f64", &inputs).unwrap();
    let b = ex.run("diffusion3d_swc_r2_f64", &inputs).unwrap();
    let err = a[0].max_abs_diff(&b[0]);
    assert!(err < 1e-13, "hwc vs swc differ by {err}");
}

#[test]
fn mhd_artifact_matches_native_engine_and_oracle() {
    let Some(ex) = executor() else { return };
    let n = 32usize;
    let entry = ex.manifest.get("mhd32_hwc_sub0_f64").unwrap().clone();
    let par: MhdParams = entry.mhd_params().expect("mhd params recorded in manifest");

    // random small-amplitude initial state
    let mut rng = Rng::new(5);
    let mut state = MhdState::from_fn(n, n, n, 3, |_, _, _, _| 1e-2 * rng.normal());
    let w0: Vec<f64> = vec![0.0; NFIELDS * n * n * n];
    let dt = 1e-4;

    // native substep
    let mut native_state = state.clone();
    let mut stepper = MhdStepper::new(par.clone(), 3, n, n, n);
    stepper.substep(&mut native_state, dt, 0);

    // artifact substep (padded input prepared by the Rust grid engine)
    state.fill_ghosts();
    let p = n + 6;
    let inputs = [
        HostValue::f64(state.stacked_padded(), &[NFIELDS, p, p, p]),
        HostValue::f64(w0.clone(), &[NFIELDS, n, n, n]),
        HostValue::scalar(dt, DType::F64),
    ];
    let out = ex.run("mhd32_hwc_sub0_f64", &inputs).unwrap();
    let got_f = out[0].to_f64_vec();
    let want_f = native_state.stacked_interior();
    let err = got_f.iter().zip(&want_f).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    assert!(err < 1e-10, "pallas vs native mismatch: {err}");

    // and against the exported jnp oracle (roll-based, unpadded input)
    let inputs_oracle = [
        HostValue::f64(state.stacked_interior(), &[NFIELDS, n, n, n]),
        HostValue::f64(w0, &[NFIELDS, n, n, n]),
        HostValue::scalar(dt, DType::F64),
    ];
    let oracle = ex.run("mhd32_oracle_sub0_f64", &inputs_oracle).unwrap();
    let err2 = oracle[0].max_abs_diff(&out[0]);
    assert!(err2 < 1e-10, "pallas vs oracle mismatch: {err2}");
}

#[test]
fn mhd_swc_equals_hwc() {
    let Some(ex) = executor() else { return };
    let n = 32usize;
    let p = n + 6;
    let mut rng = Rng::new(6);
    let mut state = MhdState::from_fn(n, n, n, 3, |_, _, _, _| 1e-2 * rng.normal());
    state.fill_ghosts();
    let inputs = [
        HostValue::f64(state.stacked_padded(), &[NFIELDS, p, p, p]),
        HostValue::f64(vec![0.0; NFIELDS * n * n * n], &[NFIELDS, n, n, n]),
        HostValue::scalar(5e-5, DType::F64),
    ];
    let a = ex.run("mhd32_hwc_sub2_f64", &inputs).unwrap();
    let b = ex.run("mhd32_swc_sub2_f64", &inputs).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-12);
    assert!(a[1].max_abs_diff(&b[1]) < 1e-12);
}

#[test]
fn library_conv_matches_handcrafted_path() {
    let Some(ex) = executor() else { return };
    let (n, r) = (1usize << 20, 4usize);
    let mut rng = Rng::new(7);
    let fpad: Vec<f32> = rng.normal_vec(n + 2 * r).iter().map(|&v| v as f32).collect();
    let taps: Vec<f32> = rng.normal_vec(2 * r + 1).iter().map(|&v| v as f32).collect();
    let inputs = [
        HostValue::f32(fpad.clone(), &[n + 2 * r]),
        HostValue::f32(taps.clone(), &[2 * r + 1]),
    ];
    let lib = ex.run("xcorr1d_lib_r4_f32", &inputs).unwrap();
    let hand = ex.run("xcorr1d_hwc_pointwise_r4_f32", &inputs).unwrap();
    // different algorithms, f32: allow a small relative tolerance
    let a = lib[0].to_f64_vec();
    let b = hand[0].to_f64_vec();
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(), "{x} vs {y}");
    }
}
