//! Integration: the regenerated figures reproduce the paper's *shapes* —
//! who wins, by roughly what factor, where crossovers fall. These are the
//! repo's headline reproduction guarantees (DESIGN.md §6).

use stencilax::config::Config;
use stencilax::harness::figures::{self, best_xcorr, mhd_best, mhd_best_tuned};
use stencilax::harness::{paper, run_figure, run_table};
use stencilax::model::specs::{spec, Gpu, ALL_GPUS, MIB};
use stencilax::sim::kernel::{Caching, Unroll};
use stencilax::sim::library::{diffusion_library_time, xcorr1d_library_time, Library};
use stencilax::sim::pitfalls;
use stencilax::sim::predict::predict;
use stencilax::sim::workloads;

fn cfg() -> Config {
    Config::default()
}

#[test]
fn fig6_shape_ramp_then_plateau_ordering() {
    // bandwidth ramps with size; at 128 MiB the *effective* ordering follows
    // peak x plateau (paper §5.2): A100 edges out MI250X despite the lower
    // peak because its utilization is higher (90% vs 84%)
    let at = |gpu: Gpu, mib: f64| {
        let prof = workloads::copy(mib * MIB, true);
        let p = predict(spec(gpu), &prof);
        prof.hbm_bytes / p.total
    };
    for gpu in ALL_GPUS {
        assert!(at(gpu, 1.0) < at(gpu, 64.0), "{gpu:?} must ramp");
    }
    let (a, v, m2, m1) =
        (at(Gpu::A100, 128.0), at(Gpu::V100, 128.0), at(Gpu::Mi250x, 128.0), at(Gpu::Mi100, 128.0));
    assert!(a > m2 && m2 > m1 && m1 > v, "ordering: {a:.2e} {m2:.2e} {m1:.2e} {v:.2e}");
}

#[test]
fn fig7_shape_nvidia_leads_library_conv_everywhere() {
    for r in figures::XCORR_RADII {
        let a = xcorr1d_library_time(spec(Gpu::A100), 1 << 24, r, false, Library::VendorDnn);
        let m = xcorr1d_library_time(spec(Gpu::Mi250x), 1 << 24, r, false, Library::VendorDnn);
        let ratio = m / a;
        assert!((1.8..=4.0).contains(&ratio), "r={r}: A100 speedup {ratio:.2} outside Fig 7 band");
    }
}

#[test]
fn fig8_shape_swc_rescues_cdna_at_large_radius() {
    let c = cfg();
    // MI250X SWC must be competitive with A100 at r=1024 FP64 — the paper:
    // "the MI250X GCD outperformed or was on par with other devices when
    // using software-managed memory"
    let (a_sw, _) = best_xcorr(&c, spec(Gpu::A100), 1024, true, Caching::Swc);
    let (m_sw, _) = best_xcorr(&c, spec(Gpu::Mi250x), 1024, true, Caching::Swc);
    assert!(m_sw <= 1.4 * a_sw, "MI250X SWC {m_sw:.2e} vs A100 {a_sw:.2e}");
    // while its HWC path lags badly
    let (m_hw, _) = best_xcorr(&c, spec(Gpu::Mi250x), 1024, true, Caching::Hwc);
    assert!(m_hw / m_sw > 1.5);
}

#[test]
fn fig8_shape_small_radius_is_bandwidth_bound_everywhere() {
    for gpu in ALL_GPUS {
        let prof = workloads::xcorr1d(
            figures::xcorr_n(true),
            1,
            true,
            Caching::Hwc,
            Unroll::Pointwise,
            workloads::TILE_1D,
        );
        let p = predict(spec(gpu), &prof);
        assert_eq!(
            p.bound,
            stencilax::sim::predict::Bound::OffChipBandwidth,
            "{gpu:?} at r=1 must be HBM-bound"
        );
    }
}

#[test]
fn fig9_shape_pointwise_pitfall_on_cdna_fp32_only() {
    // P1: on CDNA FP32 the pointwise variant must be the worst HWC variant;
    // on Nvidia it must not be
    let t = |gpu: Gpu, unroll: Unroll| {
        let prof = workloads::xcorr1d(
            figures::xcorr_n(false),
            16,
            false,
            Caching::Hwc,
            unroll,
            workloads::TILE_1D,
        );
        let prof = pitfalls::apply_unroll_pitfall(spec(gpu), prof);
        predict(spec(gpu), &prof).total
    };
    assert!(t(Gpu::Mi100, Unroll::Pointwise) > t(Gpu::Mi100, Unroll::Baseline));
    assert!(t(Gpu::A100, Unroll::Pointwise) <= t(Gpu::A100, Unroll::Baseline));
    // and FP64 subsides (Fig 9L)
    let t64 = |gpu: Gpu, unroll: Unroll| {
        let prof = workloads::xcorr1d(
            figures::xcorr_n(true),
            16,
            true,
            Caching::Hwc,
            unroll,
            workloads::TILE_1D,
        );
        let prof = pitfalls::apply_unroll_pitfall(spec(gpu), prof);
        predict(spec(gpu), &prof).total
    };
    assert!(t64(Gpu::Mi100, Unroll::Pointwise) <= t64(Gpu::Mi100, Unroll::Baseline));
}

#[test]
fn fig10_shape_mi250x_3d_collapse_at_r2() {
    // the P2 pitfall: MI250X 3-D library diffusion collapses at r>=2 while
    // smaller dimensionalities scale normally
    let t3_r1 =
        diffusion_library_time(spec(Gpu::Mi250x), &[256, 256, 256], 1, false, Library::PyTorch);
    let t3_r2 =
        diffusion_library_time(spec(Gpu::Mi250x), &[256, 256, 256], 2, false, Library::PyTorch);
    assert!(t3_r2 / t3_r1 > 50.0, "collapse factor {:.0}", t3_r2 / t3_r1);
    assert!((t3_r2 - 1.8).abs() < 0.2, "paper measured 1800 ms, model {t3_r2:.2}s");
    // A100 stays sane
    let a_r2 =
        diffusion_library_time(spec(Gpu::A100), &[256, 256, 256], 2, false, Library::PyTorch);
    assert!(a_r2 < 0.1);
}

#[test]
fn fig11_shape_nvidia_scales_better_to_large_radii_fp64() {
    // paper: "with double precision, the A100 and V100 scale more
    // efficiently to larger stencil radii" — r=4/r=1 growth must be larger
    // on the 8-MiB-L2 CDNA parts than on the A100
    let growth = |gpu: Gpu| {
        let t1 = figures::diffusion_best(spec(gpu), 3, 1, true, Caching::Hwc);
        let t4 = figures::diffusion_best(spec(gpu), 3, 4, true, Caching::Hwc);
        t4 / t1
    };
    assert!(growth(Gpu::Mi250x) > growth(Gpu::A100));
    assert!(growth(Gpu::Mi100) > growth(Gpu::A100));
}

#[test]
fn fig12_shape_hwc_wins_diffusion_everywhere() {
    // paper Fig. 12: "The hardware-cached implementation provided the best
    // performance on all devices"
    for gpu in ALL_GPUS {
        for fp64 in [false, true] {
            let hw = figures::diffusion_best(spec(gpu), 3, 2, fp64, Caching::Hwc);
            let sw = figures::diffusion_best(spec(gpu), 3, 2, fp64, Caching::Swc);
            assert!(hw <= sw, "{gpu:?} fp64={fp64}: hw {hw:.2e} sw {sw:.2e}");
        }
    }
}

#[test]
fn fig13_shape_hwc_advantage_band() {
    // paper: HWC 1.8-2.9x faster (FP32), 2.4-8.1x (FP64); require >= 1.5x
    for gpu in ALL_GPUS {
        for fp64 in [false, true] {
            let hw = mhd_best_tuned(spec(gpu), fp64, Caching::Hwc);
            let sw = mhd_best_tuned(spec(gpu), fp64, Caching::Swc);
            assert!(sw / hw >= 1.5, "{gpu:?} fp64={fp64}: {:.2}", sw / hw);
        }
    }
}

#[test]
fn fig14_shape_default_best_on_nvidia_tuning_needed_on_cdna() {
    // paper: "the register allocation had to be manually tuned to achieve
    // the highest performance on the MI100 and MI250X"
    for gpu in [Gpu::A100, Gpu::V100] {
        let default = mhd_best(spec(gpu), true, Caching::Hwc, 0);
        let tuned = mhd_best_tuned(spec(gpu), true, Caching::Hwc);
        assert!(tuned >= default * 0.999, "{gpu:?}: default must already be optimal");
    }
    for gpu in [Gpu::Mi250x, Gpu::Mi100] {
        let default = mhd_best(spec(gpu), true, Caching::Hwc, 0);
        let tuned = mhd_best_tuned(spec(gpu), true, Caching::Hwc);
        assert!(
            tuned < default * 0.97,
            "{gpu:?}: manual launch_bounds must help (default {default:.3e}, tuned {tuned:.3e})"
        );
    }
}

#[test]
fn energy_shape_table3_headline() {
    // MI250X best at 1-D xcorr energy; A100 best at MHD energy
    let c = cfg();
    let out = run_table(&c, "table3").unwrap();
    let t = &out.tables[0];
    let val = |row: usize, col: usize| t.rows[row][col].parse::<f64>().unwrap();
    // row 0 = xcorr FP32 r=1: A100 col 3, MI250X col 5
    assert!(val(0, 5) > val(0, 3));
    // rows 4/5 = MHD: A100 must lead all
    for row in [4, 5] {
        for col in [4, 5, 6] {
            assert!(val(row, 3) > val(row, col), "row {row} col {col}");
        }
    }
    let _ = val;
}

#[test]
fn paper_claims_mostly_pass() {
    let c = cfg();
    let all = paper::claims(&c);
    let passed = all.iter().filter(|cl| cl.passed()).count();
    assert!(passed * 100 >= all.len() * 85, "{passed}/{} claims", all.len());
}

#[test]
fn all_figures_and_tables_regenerate() {
    let c = cfg();
    for id in stencilax::harness::FIGURE_IDS {
        assert!(!run_figure(&c, id).unwrap().tables.is_empty(), "{id}");
    }
    for id in stencilax::harness::TABLE_IDS {
        assert!(!run_table(&c, id).unwrap().tables.is_empty(), "{id}");
    }
}
