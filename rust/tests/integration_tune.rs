//! Integration: the workload registry and the batched parallel autotune
//! service — golden decompositions on the paper's devices, determinism
//! across worker-thread counts, prediction-cache invariance, and the
//! `util::par` thread-count override the batch fans out on.
//!
//! This file owns every test that touches `STENCILAX_THREADS`: integration
//! tests run in their own process, and every test here — mutators *and*
//! readers (anything reaching `par::num_threads`) — holds `ENV_LOCK`, so
//! `set_var` never races a concurrent `getenv` from a sibling test thread.

use std::sync::{Mutex, MutexGuard};

use stencilax::coordinator::tune::{autotune_cached, tune_batch, PredictionCache, TuneReport};
use stencilax::model::specs::{spec, Gpu, GpuSpec, ALL_GPUS};
use stencilax::prop_assert;
use stencilax::sim::kernel::Caching;
use stencilax::sim::workload::{find, registry, Workload};
use stencilax::sim::workloads::Tile;
use stencilax::util::json::Json;
use stencilax::util::par;
use stencilax::util::prop::check;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Serialize the whole binary: poison-tolerant so one failing test does
/// not cascade into every later lock acquisition.
fn env_guard() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn all_workloads() -> Vec<&'static dyn Workload> {
    registry().iter().map(|w| w.as_ref()).collect()
}

fn serialize(reports: &[TuneReport]) -> String {
    reports
        .iter()
        .map(|r| r.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

fn best_tile(name: &str, gpu: Gpu) -> Tile {
    let w = find(name).unwrap_or_else(|| panic!("workload {name} not registered"));
    let reports = tune_batch(&[w], &[spec(gpu)], true, Caching::Hwc, &PredictionCache::new());
    reports[0].best().unwrap_or_else(|| panic!("{name} on {gpu}: empty search")).tile
}

// ---------------------------------------------------------------------------
// golden decompositions (paper §5.1 search, FP64, hardware caching)
// ---------------------------------------------------------------------------

#[test]
fn golden_best_tiles_on_a100_and_mi250x() {
    let _guard = env_guard();
    let t = |tx, ty, tz| Tile { tx, ty, tz };
    // Pinned winners of the pruned search, verified against an independent
    // reimplementation of the performance model. 1-D workloads are
    // tile-indifferent under hardware caching, so the smallest warp-aligned
    // block wins by the deterministic tie-break; the 2-D/3-D workloads pick
    // the minimal-halo decompositions.
    let pins: &[(&str, Tile, Tile)] = &[
        // (workload, best on A100, best on MI250X)
        ("conv1d-r1", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r2", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r3", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r4", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r5", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r6", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r7", t(32, 1, 1), t(64, 1, 1)),
        ("conv1d-r8", t(32, 1, 1), t(64, 1, 1)),
        ("xcorr", t(32, 1, 1), t(64, 1, 1)),
        ("diffusion1d", t(32, 1, 1), t(64, 1, 1)),
        ("diffusion2d", t(64, 16, 1), t(64, 16, 1)),
        ("diffusion3d", t(8, 16, 8), t(8, 16, 8)),
        ("mhd", t(8, 16, 8), t(8, 16, 8)),
    ];
    for (name, on_a100, on_mi250x) in pins {
        assert_eq!(best_tile(name, Gpu::A100), *on_a100, "{name} on A100");
        assert_eq!(best_tile(name, Gpu::Mi250x), *on_mi250x, "{name} on MI250X");
    }
}

#[test]
fn every_reported_tile_obeys_the_pruning_rules() {
    let _guard = env_guard();
    // paper §5.1: tx a multiple of (L2 line / sizeof(double)) = 8, thread
    // count a warp-size multiple within [warp, 1024]
    for gpu in [Gpu::A100, Gpu::Mi250x] {
        let dev = spec(gpu);
        let reports =
            tune_batch(&all_workloads(), &[dev], true, Caching::Hwc, &PredictionCache::new());
        assert_eq!(reports.len(), registry().len());
        for r in &reports {
            assert!(r.valid > 0, "{}: no valid decomposition on {gpu}", r.workload);
            for res in &r.results {
                assert_eq!(res.tile.tx % 8, 0, "{}: tx % 8", r.workload);
                assert_eq!(res.tile.threads() % dev.warp_size(), 0, "{}", r.workload);
                assert!(res.tile.threads() >= dev.warp_size(), "{}", r.workload);
                assert!(res.tile.threads() <= 1024, "{}", r.workload);
                assert!(res.time_s > 0.0 && res.time_s.is_finite(), "{}", r.workload);
            }
        }
    }
}

#[test]
fn swc_searches_discard_oversized_shared_memory_tiles() {
    let _guard = env_guard();
    // the "failed launch" discard rule must leave SWC searches non-empty
    // but strictly smaller than the enumerated space on 64-KiB-LDS devices
    let w = find("mhd").unwrap();
    let reports =
        tune_batch(&[w], &[spec(Gpu::Mi250x)], true, Caching::Swc, &PredictionCache::new());
    let r = &reports[0];
    assert!(r.valid > 0);
    assert!(r.valid < r.searched, "SWC must prune some of {} tiles", r.searched);
}

// ---------------------------------------------------------------------------
// determinism across worker-thread counts
// ---------------------------------------------------------------------------

#[test]
fn tune_batch_identical_under_one_and_eight_threads() {
    let _guard = env_guard();
    let specs = [spec(Gpu::A100), spec(Gpu::Mi250x)];

    std::env::set_var("STENCILAX_THREADS", "1");
    assert_eq!(par::num_threads(), 1);
    let serial = tune_batch(&all_workloads(), &specs, true, Caching::Hwc, &PredictionCache::new());

    std::env::set_var("STENCILAX_THREADS", "8");
    assert_eq!(par::num_threads(), 8);
    let parallel =
        tune_batch(&all_workloads(), &specs, true, Caching::Hwc, &PredictionCache::new());

    std::env::remove_var("STENCILAX_THREADS");

    assert_eq!(serial.len(), parallel.len());
    assert_eq!(
        serialize(&serial),
        serialize(&parallel),
        "reports must be bit-identical regardless of worker count"
    );
}

// ---------------------------------------------------------------------------
// prediction-cache invariance (property tests)
// ---------------------------------------------------------------------------

#[test]
fn prop_prediction_cache_never_changes_results() {
    let _guard = env_guard();
    check("cache invariance", 12, |rng| {
        let reg = registry();
        let w: &dyn Workload = reg[rng.below(reg.len())].as_ref();
        let dev = spec(*rng.choice(&ALL_GPUS));
        let fp64 = rng.uniform() < 0.5;
        let caching = if rng.uniform() < 0.5 { Caching::Hwc } else { Caching::Swc };

        let shared = PredictionCache::new();
        let cold = tune_batch(&[w], &[dev], fp64, caching, &PredictionCache::new());
        let warm = tune_batch(&[w], &[dev], fp64, caching, &shared);
        let hits_before = shared.hits();
        let reheated = tune_batch(&[w], &[dev], fp64, caching, &shared);

        prop_assert!(shared.hits() > hits_before, "rerun must hit the cache");
        prop_assert!(
            serialize(&cold) == serialize(&warm),
            "fresh vs shared cache diverged for {} on {}",
            w.name(),
            dev.name
        );
        prop_assert!(
            serialize(&warm) == serialize(&reheated),
            "cached rerun diverged for {} on {}",
            w.name(),
            dev.name
        );
        Ok(())
    });
}

#[test]
fn prop_cached_search_equals_uncached_autotune() {
    let _guard = env_guard();
    use stencilax::coordinator::autotune::autotune;
    use stencilax::sim::workloads;
    check("cached == uncached", 10, |rng| {
        let dev: &GpuSpec = spec(*rng.choice(&ALL_GPUS));
        let r = 1 + rng.below(4);
        let fp64 = rng.uniform() < 0.5;
        let build = move |tile| {
            Some(workloads::diffusion(dev, &[128, 128, 128], r, fp64, Caching::Hwc, tile))
        };
        let plain = autotune(dev, 3, build);
        let cache = PredictionCache::new();
        let cached = autotune_cached(dev, 3, "prop", &cache, build);
        prop_assert!(plain.len() == cached.len(), "result count diverged");
        for (a, b) in plain.iter().zip(&cached) {
            prop_assert!(a.tile == b.tile, "order diverged at {:?} vs {:?}", a.tile, b.tile);
            prop_assert!(a.time_s == b.time_s, "time diverged");
            prop_assert!(a.occupancy == b.occupancy, "occupancy diverged");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// util::par — the substrate the batch fans out on
// ---------------------------------------------------------------------------

#[test]
fn par_map_thread_count_env_override() {
    let _guard = env_guard();
    std::env::set_var("STENCILAX_THREADS", "3");
    assert_eq!(par::num_threads(), 3);
    // order preserved under the override
    let got = par::par_map(97, |i| i * 3 + 1);
    assert_eq!(got, (0..97).map(|i| i * 3 + 1).collect::<Vec<_>>());

    // zero clamps to one worker
    std::env::set_var("STENCILAX_THREADS", "0");
    assert_eq!(par::num_threads(), 1);

    // garbage falls back to machine parallelism
    std::env::set_var("STENCILAX_THREADS", "not-a-number");
    assert!(par::num_threads() >= 1);

    std::env::remove_var("STENCILAX_THREADS");
}

#[test]
fn par_map_edge_cases_empty_and_single() {
    let _guard = env_guard();
    assert_eq!(par::par_map(0, |i| i * 2), Vec::<usize>::new());
    assert_eq!(par::par_map(1, |i| i + 41), vec![41]);
    // n smaller than the worker count still covers every index once
    let v = par::par_map(3, |i| i);
    assert_eq!(v, vec![0, 1, 2]);
}

// ---------------------------------------------------------------------------
// report serialization contract
// ---------------------------------------------------------------------------

#[test]
fn tune_reports_roundtrip_through_json() {
    let _guard = env_guard();
    let specs = [spec(Gpu::A100), spec(Gpu::Mi250x)];
    let reports =
        tune_batch(&all_workloads(), &specs, true, Caching::Hwc, &PredictionCache::new());
    assert_eq!(reports.len(), registry().len() * specs.len());

    let arr = Json::arr(reports.iter().map(|r| r.to_json()).collect());
    let parsed = Json::parse(&arr.to_string_pretty()).expect("reports must be valid JSON");
    let items = parsed.as_arr().unwrap();
    assert_eq!(items.len(), reports.len());
    for (j, r) in items.iter().zip(&reports) {
        assert_eq!(j.req_str("workload").unwrap(), r.workload);
        assert_eq!(j.req_str("gpu").unwrap(), r.gpu);
        assert_eq!(j.req_str("precision").unwrap(), "f64");
        assert!(j.req_f64("best_time_ms").unwrap() > 0.0);
        assert_eq!(j.req_arr("best_tile").unwrap().len(), 3);
        assert_eq!(j.req_u64("valid").unwrap() as usize, r.valid);
    }
}
