//! Plan invariance (ISSUE 3 satellite, extended by ISSUEs 8 and 9): every
//! candidate [`LaunchPlan`] must produce results identical to the default
//! plan — row blocking, thread budget, chunk length, workspace strategy,
//! SIMD lane width, and temporal-blocking depth only reassign work to
//! threads, registers, and cache residencies, never change arithmetic.
//! Plans sharing a fusion mode must match **bit for bit** at EVERY lane
//! width and EVERY depth (the vector microkernels in `stencil::simd`
//! preserve the scalar per-element reduction order by construction, and
//! the trapezoidal tiles in `stencil::temporal` compute every
//! intermediate cell from the same periodic extension the classic loop
//! sees); the unfused MHD candidate evaluates a genuinely different
//! (reference) path and is held to the established fused-parity tolerance
//! (<= 1e-12, `rust/tests/fused_parity.rs`) instead. The tolerance class
//! is asserted per workload, not globally.
//!
//! Candidates come from the real enumerator
//! (`coordinator::empirical::candidate_plans`), swept across thread
//! budgets {1, 2, 4} and explicitly crossed with every
//! [`Lanes`] width and every depth up to [`MAX_DEPTH`], so exactly the
//! plans the tuner can pick are the plans pinned here.

use stencilax::coordinator::empirical::candidate_plans;
use stencilax::prop_assert;
use stencilax::stencil::conv;
use stencilax::stencil::diffusion::Diffusion;
use stencilax::stencil::exec::DoubleBuffer;
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::stencil::mhd::{MhdParams, MhdState, MhdStepper};
use stencilax::stencil::plan::{Lanes, LaunchPlan, MAX_DEPTH};
use stencilax::stencil::temporal::TemporalScheduler;
use stencilax::util::prop::check;
use stencilax::util::rng::Rng;

/// The tuner's candidate set, swept over explicit thread budgets.
fn plans_for(
    shape: &[usize],
    chunked: bool,
    include_unfused: bool,
    include_depth: bool,
) -> Vec<LaunchPlan> {
    let mut plans = Vec::new();
    for threads in [1usize, 2, 4] {
        for p in candidate_plans(shape, threads, chunked, include_unfused, include_depth) {
            if !plans.contains(&p) {
                plans.push(p);
            }
        }
    }
    plans
}

/// The full lane-width cross product over the candidate set: every
/// candidate at every [`Lanes`] width, deduplicated. The enumerator only
/// emits lane variants of the per-kind base plan (and none under
/// `STENCILAX_FORCE_SCALAR`); parity must hold for the complete product
/// regardless, because a cached plan from an earlier tuning can combine
/// any block/chunk/workspace choice with any width.
fn lane_cross(shape: &[usize], chunked: bool, include_unfused: bool) -> Vec<LaunchPlan> {
    let mut out = Vec::new();
    for base in plans_for(shape, chunked, include_unfused, false) {
        for lanes in Lanes::ALL {
            let p = LaunchPlan { lanes, ..base };
            if !out.contains(&p) {
                out.push(p);
            }
        }
    }
    out
}

/// The depth × lane cross product over the candidate set: every candidate
/// at every depth up to [`MAX_DEPTH`] at every [`Lanes`] width. As with
/// `lane_cross`, the enumerator only emits depth variants of the base
/// plan, but a cached plan from an earlier tuning can combine any depth
/// with any block/chunk/workspace/lane choice — the full product must be
/// invariant.
fn depth_lane_cross(shape: &[usize], chunked: bool) -> Vec<LaunchPlan> {
    let mut out = Vec::new();
    for base in lane_cross(shape, chunked, false) {
        for depth in 1..=MAX_DEPTH {
            let p = LaunchPlan { depth, ..base };
            if !out.contains(&p) {
                out.push(p);
            }
        }
    }
    out
}

#[test]
fn diffusion_1_2_3d_bit_identical_across_candidate_plans_and_lane_widths() {
    for (dim, shape) in [
        (1usize, vec![257usize]),
        (2, vec![33, 29]),
        (3, vec![17, 13, 11]),
    ] {
        let mut rng = Rng::new(7 + dim as u64);
        let mut src = Grid::from_fn(&shape, 3, |_, _, _| rng.normal());
        src.fill_ghosts(Boundary::Periodic);
        let d = Diffusion::new(3, 0.9, 1.0, Boundary::Periodic);
        let dt = d.stable_dt(dim);
        let (nx, ny, nz) = (src.nx, src.ny, src.nz);
        let mut want = Grid::new(nx, ny, nz, 3);
        d.step_into(&src, &mut want, dim, dt);
        let want = want.interior_to_vec();
        // grid candidates for the real dimensionality, plus the chunked
        // 1-D set — the grid path ignores plan.chunk, so both must be
        // bit-identical no matter what. Tolerance class: bit-identical at
        // EVERY lane width (register blocking preserves reduction order).
        let mut plans = lane_cross(&shape, false, false);
        plans.extend(lane_cross(&shape, true, false));
        for plan in plans {
            let mut got = Grid::new(nx, ny, nz, 3);
            d.step_into_plan(&plan, &src, &mut got, dim, dt);
            assert_eq!(got.interior_to_vec(), want, "dim={dim} plan={plan:?}");
        }
    }
}

#[test]
fn xcorr1d_bit_identical_across_chunk_plans_and_lane_widths() {
    let mut rng = Rng::new(11);
    let (n, r) = (10_000usize, 4usize);
    let fpad = rng.normal_vec(n + 2 * r);
    let taps = rng.normal_vec(2 * r + 1);
    let want = conv::xcorr1d(&fpad, &taps);
    // tolerance class: bit-identical at every lane width (the vector tap
    // loop accumulates in the same per-element order as the reference)
    for plan in lane_cross(&[n], true, false) {
        assert_eq!(conv::xcorr1d_plan(&plan, &fpad, &taps), want, "{plan:?}");
    }
}

#[test]
fn fused_mhd_bit_identical_unfused_within_parity_tolerance_at_every_lane_width() {
    let n = 8usize;
    let par = MhdParams { dx: 2.0 * std::f64::consts::PI / n as f64, ..Default::default() };
    let mut rng = Rng::new(3);
    let st0 = MhdState::from_fn(n, n, n, 3, |_, _, _, _| 1e-2 * rng.normal());

    let advance = |plan: &LaunchPlan| -> MhdState {
        let mut st = st0.clone();
        let mut stepper = MhdStepper::new(par.clone(), 3, n, n, n);
        let dt = 1e-3;
        for l in 0..3 {
            stepper.substep_plan(plan, &mut st, dt, l);
        }
        st
    };
    let want = advance(&LaunchPlan::default_for(&[n, n, n], 0));
    // tolerance class per path: fused plans (any lane width) are
    // bit-identical — the ~60 per-row contractions preserve the scalar
    // op order in every vector microkernel; the unfused candidates run
    // the reference composition and keep the established <= 1e-12 bound
    for plan in lane_cross(&[n, n, n], false, true) {
        let got = advance(&plan);
        let err = got
            .fields
            .iter()
            .zip(&want.fields)
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0, f64::max);
        if plan.fused {
            assert_eq!(err, 0.0, "fused plan diverged: {plan:?} (err {err:e})");
        } else {
            assert!(err <= 1e-12, "unfused plan outside tolerance: {plan:?} (err {err:e})");
        }
    }
}

#[test]
fn prop_random_2d_shapes_are_plan_invariant() {
    check("plan invariance on random 2-D shapes", 8, |rng| {
        let nx = 3 + (rng.uniform() * 40.0) as usize;
        let ny = 1 + (rng.uniform() * 24.0) as usize;
        let radius = 1 + (rng.uniform() * 3.0) as usize;
        let mut src = Grid::from_fn(&[nx, ny], radius, |_, _, _| rng.normal());
        src.fill_ghosts(Boundary::Periodic);
        let d = Diffusion::new(radius, 0.7, 1.0, Boundary::Periodic);
        let dt = d.stable_dt(2);
        let mut want = Grid::new(nx, ny, 1, radius);
        d.step_into(&src, &mut want, 2, dt);
        let want = want.interior_to_vec();
        for plan in candidate_plans(&[nx, ny], 4, false, false, false) {
            let mut got = Grid::new(nx, ny, 1, radius);
            d.step_into_plan(&plan, &src, &mut got, 2, dt);
            prop_assert!(
                got.interior_to_vec() == want,
                "plan {plan:?} diverged on {nx}x{ny} r={radius}"
            );
        }
        Ok(())
    });
}

#[test]
fn diffusion_temporal_chunks_bit_identical_across_depth_lane_and_candidate_plans() {
    // ISSUE 9 satellite: the trapezoidal temporal tiles must be invisible
    // to the numbers — any candidate plan at any depth and lane width
    // advances a multi-step run to the exact bits the classic
    // one-sweep-per-residency loop produces. Tolerance class:
    // bit-identical (same fused diffusion kernels, same reduction order,
    // periodic extension is shift-invariant).
    for (dim, shape) in [
        (1usize, vec![97usize]),
        (2, vec![23, 19]),
        (3, vec![11, 9, 7]),
    ] {
        let mut rng = Rng::new(29 + dim as u64);
        let radius = 2;
        let seed = Grid::from_fn(&shape, radius, |_, _, _| rng.normal());
        let d = Diffusion::new(radius, 0.9, 1.0, Boundary::Periodic);
        let dt = d.stable_dt(dim);
        let steps = 2 * MAX_DEPTH + 1; // exercises a partial tail chunk
        let mut want = DoubleBuffer::new(seed.clone());
        for _ in 0..steps {
            d.step_buffered(&mut want, dim, dt);
        }
        let want = want.cur().interior_to_vec();
        for plan in depth_lane_cross(&shape, false) {
            let mut got = DoubleBuffer::new(seed.clone());
            let mut sched = TemporalScheduler::new();
            sched.advance(&d, &plan, &mut got, dim, dt, steps);
            assert_eq!(got.cur().interior_to_vec(), want, "dim={dim} plan={plan:?}");
        }
    }
}

#[test]
fn xcorr_chain_bit_identical_across_depth_lane_and_chunk_plans() {
    // the 1-D stencil chain: per-chunk trapezoids advance every output
    // chunk through all stages while cache-resident. Tolerance class:
    // bit-identical at every lane width and depth (per-element values
    // depend only on the input window; the vector tap loop preserves the
    // reference accumulation order).
    let mut rng = Rng::new(41);
    let (n, r, stages) = (2_048usize, 3usize, 3usize);
    let fpad = rng.normal_vec(n + stages * 2 * r);
    let taps = rng.normal_vec(2 * r + 1);
    let want = conv::xcorr1d_chain(&fpad, &taps, stages);
    for plan in depth_lane_cross(&[n], true) {
        let mut out = vec![0.0f64; n];
        conv::xcorr1d_chain_plan(&plan, &fpad, &taps, stages, &mut out);
        assert_eq!(out, want, "{plan:?}");
    }
}

#[test]
fn prop_temporal_tiles_never_read_unfilled_ghosts() {
    // the temporal scratch field NaN-fills its ghost pads and only
    // overwrites them out to the per-axis widened halo (depth * radius);
    // a sweep band that reached past what `fill_ghosts_periodic` filled
    // would pull the NaN sentinel straight into the interior. Random
    // shapes (including domains smaller than the widened halo, where the
    // periodic extension wraps multiple times), radii, depths, and step
    // counts must therefore stay finite AND bit-equal to the classic loop.
    check("temporal halo widening on random shapes", 12, |rng| {
        let dim = 1 + (rng.uniform() * 3.0) as usize;
        let radius = 1 + (rng.uniform() * 3.0) as usize;
        let depth = 1 + (rng.uniform() * MAX_DEPTH as f64) as usize;
        let mut shape = Vec::new();
        for _ in 0..dim.min(3) {
            shape.push(3 + (rng.uniform() * 20.0) as usize);
        }
        let dim = shape.len();
        let seed = Grid::from_fn(&shape, radius, |_, _, _| rng.normal());
        let d = Diffusion::new(radius, 0.8, 1.0, Boundary::Periodic);
        let dt = d.stable_dt(dim);
        let steps = depth + (rng.uniform() * 3.0) as usize;
        let plan = LaunchPlan { depth: depth.min(MAX_DEPTH), ..LaunchPlan::default_for(&shape, 2) };
        let mut want = DoubleBuffer::new(seed.clone());
        for _ in 0..steps {
            d.step_buffered(&mut want, dim, dt);
        }
        let mut got = DoubleBuffer::new(seed);
        let mut sched = TemporalScheduler::new();
        sched.advance(&d, &plan, &mut got, dim, dt, steps);
        let got = got.cur().interior_to_vec();
        prop_assert!(
            got.iter().all(|v| v.is_finite()),
            "NaN ghost sentinel leaked: shape={shape:?} r={radius} depth={depth}"
        );
        prop_assert!(
            got == want.cur().interior_to_vec(),
            "temporal tiles diverged: shape={shape:?} r={radius} depth={depth} steps={steps}"
        );
        Ok(())
    });
}
