//! Property-based tests on coordinator and engine invariants (the offline
//! substitute for proptest — see rust/src/util/prop.rs; every property runs
//! over deterministic pseudo-random cases with reproducible seeds).

use stencilax::coordinator::autotune::{autotune, candidate_tiles};
use stencilax::coordinator::verify::{ulp_diff, verify_slices, Tolerance};
use stencilax::model::specs::{spec, ALL_GPUS};
use stencilax::prop_assert;
use stencilax::sim::kernel::{Caching, Unroll};
use stencilax::sim::predict::predict;
use stencilax::sim::workloads::{self, Tile};
use stencilax::stencil::coeffs::central_weights;
use stencilax::stencil::conv;
use stencilax::stencil::diffusion::Diffusion;
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::util::json::Json;
use stencilax::util::prop::check;
use stencilax::util::rng::Rng;

// ---------------------------------------------------------------------------
// stencil engine invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_xcorr1d_is_linear() {
    // xcorr(a*f + b*g, taps) == a*xcorr(f) + b*xcorr(g)
    check("xcorr linearity", 50, |rng| {
        let n = 32 + rng.below(256);
        let r = 1 + rng.below(5);
        let taps = rng.normal_vec(2 * r + 1);
        let f = rng.normal_vec(n + 2 * r);
        let g = rng.normal_vec(n + 2 * r);
        let (a, b) = (rng.range(-2.0, 2.0), rng.range(-2.0, 2.0));
        let combo: Vec<f64> = f.iter().zip(&g).map(|(x, y)| a * x + b * y).collect();
        let lhs = conv::xcorr1d(&combo, &taps);
        let fa = conv::xcorr1d(&f, &taps);
        let gb = conv::xcorr1d(&g, &taps);
        for i in 0..lhs.len() {
            let want = a * fa[i] + b * gb[i];
            prop_assert!(
                (lhs[i] - want).abs() < 1e-10 * (1.0 + want.abs()),
                "at {i}: {} vs {want}",
                lhs[i]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_xcorr_identity_taps() {
    check("identity taps pass through", 30, |rng| {
        let n = 16 + rng.below(128);
        let r = 1 + rng.below(4);
        let mut taps = vec![0.0; 2 * r + 1];
        taps[r] = 1.0;
        let f = rng.normal_vec(n + 2 * r);
        let out = conv::xcorr1d(&f, &taps);
        prop_assert!(out == f[r..r + n], "identity must be exact");
        Ok(())
    });
}

#[test]
fn prop_diffusion_conserves_mean_and_contracts() {
    check("diffusion mean + contraction", 25, |rng| {
        let n = 8 + 2 * rng.below(8);
        let r = 1 + rng.below(3);
        let mut g = Grid::from_fn(&[n, n, n.min(8)], r, |_, _, _| rng.normal());
        let d = Diffusion::new(r, rng.range(0.1, 2.0), rng.range(0.2, 1.0), Boundary::Periodic);
        let dt = d.stable_dt(3) * rng.range(0.2, 1.0);
        let out = d.step(&mut g, 3, dt);
        prop_assert!((out.mean() - g.mean()).abs() < 1e-10, "mean drifted");
        prop_assert!(out.max_abs() <= g.max_abs() * (1.0 + 1e-12), "max grew");
        Ok(())
    });
}

#[test]
fn prop_central_weights_annihilate_low_polynomials() {
    check("FD order conditions", 40, |rng| {
        let r = 1 + rng.below(5);
        let d = 1 + rng.below(2);
        let w = central_weights(d, r);
        // random low-degree polynomial p(x): weights must produce p^(d)(0)
        let degree = rng.below((2 * r).min(4)) + 1;
        let coef = rng.normal_vec(degree + 1);
        let eval = |x: f64| coef.iter().enumerate().map(|(k, c)| c * x.powi(k as i32)).sum::<f64>();
        let got: f64 =
            w.iter().zip(-(r as i64)..=r as i64).map(|(c, x)| c * eval(x as f64)).sum();
        let want = match d {
            1 => {
                if degree >= 1 {
                    coef[1]
                } else {
                    0.0
                }
            }
            _ => {
                if degree >= 2 {
                    2.0 * coef[2]
                } else {
                    0.0
                }
            }
        };
        prop_assert!((got - want).abs() < 1e-7 * (1.0 + want.abs()), "{got} vs {want}");
        Ok(())
    });
}

#[test]
fn prop_grid_roundtrip_any_shape() {
    check("grid interior roundtrip", 40, |rng| {
        let shape = [1 + rng.below(24), 1 + rng.below(12), 1 + rng.below(8)];
        let r = 1 + rng.below(4);
        let data = rng.normal_vec(shape.iter().product());
        let mut g = Grid::new_nd(&shape, r);
        g.interior_from_slice(&data);
        prop_assert!(g.interior_to_vec() == data, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_periodic_ghosts_match_modular_indexing() {
    check("periodic ghost fill", 20, |rng| {
        let (nx, ny, nz) = (2 + rng.below(6), 2 + rng.below(6), 2 + rng.below(6));
        let r = 1 + rng.below(3);
        let mut g = Grid::from_fn(&[nx, ny, nz], r, |_, _, _| rng.normal());
        g.fill_ghosts(Boundary::Periodic);
        let (px, py, pz) = g.padded();
        for _ in 0..50 {
            let (pi, pj, pk) = (rng.below(px), rng.below(py), rng.below(pz));
            let want = g.get(
                (pi as i64 - r as i64).rem_euclid(nx as i64) as usize,
                (pj as i64 - r as i64).rem_euclid(ny as i64) as usize,
                (pk as i64 - r as i64).rem_euclid(nz as i64) as usize,
            );
            prop_assert!(g.data()[g.pidx(pi, pj, pk)] == want, "ghost mismatch");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_predictions_positive_and_bounded_by_components() {
    check("prediction sanity", 60, |rng| {
        let dev = spec(*rng.choice(&ALL_GPUS));
        let r = 1 + rng.below(512);
        let caching = *rng.choice(&[Caching::Hwc, Caching::Swc]);
        let unroll = *rng.choice(&Unroll::ALL);
        let prof =
            workloads::xcorr1d(1 << 20, r, rng.uniform() < 0.5, caching, unroll, workloads::TILE_1D);
        let p = predict(dev, &prof);
        prop_assert!(p.total.is_finite() && p.total > 0.0, "bad total {}", p.total);
        prop_assert!(
            p.total + 1e-18 >= p.t_hbm.max(p.t_onchip).max(p.t_flop),
            "total below components"
        );
        prop_assert!((0.0..=1.0).contains(&p.occupancy.fraction), "occupancy out of range");
        prop_assert!((0.0..=1.0).contains(&p.issue_eff), "issue eff out of range");
        Ok(())
    });
}

#[test]
fn prop_time_monotone_in_radius() {
    check("radius monotonicity", 30, |rng| {
        let dev = spec(*rng.choice(&ALL_GPUS));
        let fp64 = rng.uniform() < 0.5;
        let mut last = 0.0f64;
        for r in [1usize, 4, 16, 64, 256] {
            let prof = workloads::xcorr1d(
                1 << 22,
                r,
                fp64,
                Caching::Swc,
                Unroll::Pointwise,
                workloads::TILE_1D,
            );
            let t = predict(dev, &prof).total;
            prop_assert!(t >= last, "time decreased with radius at r={r}");
            last = t;
        }
        Ok(())
    });
}

#[test]
fn prop_autotune_best_dominates_every_candidate() {
    check("autotune optimality", 10, |rng| {
        let dev = spec(*rng.choice(&ALL_GPUS));
        let fp64 = rng.uniform() < 0.5;
        let results = autotune(dev, 3, |tile: Tile| {
            Some(workloads::diffusion(dev, &[128, 128, 128], 2, fp64, Caching::Hwc, tile))
        });
        prop_assert!(!results.is_empty(), "no candidates");
        let best = results[0].time_s;
        for r in &results {
            prop_assert!(best <= r.time_s + 1e-18, "non-minimal best");
        }
        // every candidate obeys the pruning rules
        for t in candidate_tiles(dev, 3) {
            prop_assert!(t.threads() % dev.warp_size() == 0, "warp-size rule violated");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_values() {
    check("json roundtrip", 60, |rng| {
        // build a random JSON tree
        fn build(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.uniform() < 0.5),
                2 => Json::Num((rng.normal() * 1e6).round()),
                3 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(4)).map(|_| build(rng, depth - 1)).collect()),
                _ => Json::obj(
                    [("a", build(rng, depth - 1)), ("b", build(rng, depth - 1))].into(),
                ),
            }
        }
        let v = build(rng, 3);
        let compact = Json::parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
        prop_assert!(compact == v, "compact roundtrip");
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        prop_assert!(pretty == v, "pretty roundtrip");
        Ok(())
    });
}

#[test]
fn prop_verify_accepts_self_and_ulp_metric_is_symmetricish() {
    check("verify self-comparison", 40, |rng| {
        let v = rng.normal_vec(100);
        let rep = verify_slices(&v, &v, Tolerance::Exact);
        prop_assert!(rep.passed && rep.failures == 0, "self-compare failed");
        let (a, b) = (rng.normal(), rng.normal());
        if a != 0.0 && b != 0.0 && (a - b).abs() / b.abs() < 0.5 {
            let d1 = ulp_diff(a, b);
            let d2 = ulp_diff(b, a);
            prop_assert!(
                (d1 - d2).abs() <= 0.5 * d1.max(d2).max(1.0),
                "ulp metric wildly asymmetric"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_par_map_equals_serial_map() {
    check("par_map == map", 20, |rng| {
        let n = rng.below(500);
        let xs = rng.normal_vec(n.max(1));
        let par = stencilax::util::par::par_map(xs.len(), |i| xs[i] * 2.0 + 1.0);
        let ser: Vec<f64> = xs.iter().map(|x| x * 2.0 + 1.0).collect();
        prop_assert!(par == ser, "parallel map diverged");
        Ok(())
    });
}
