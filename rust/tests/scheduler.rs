//! Scheduler regressions for the daemon (ISSUE 6): the cost-aware queue
//! must fix FIFO head-of-line blocking — cheap jobs complete before a
//! long session that arrived first, both by pop order and by
//! step-granularity preemption when they arrive mid-run — while every
//! session's bit digest stays identical to its FIFO twin; admission
//! control must reject deadline-bearing jobs the predicted backlog
//! already dooms, answering with `predicted_wait_s`; and a zero
//! `--queue-cap` must be a configuration error, not a silent clamp.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use stencilax::coordinator::daemon::{drive, server, DaemonOpts, Event, JobQueue, Policy};
use stencilax::coordinator::service::{admit, JobSpec, Session, SessionResult};

fn spec(workload: &str, shape: &[usize], steps: usize) -> JobSpec {
    JobSpec { workload: workload.into(), shape: shape.to_vec(), steps, ..JobSpec::default() }
}

fn session(id: usize, workload: &str, shape: &[usize], steps: usize) -> Session {
    admit(id, spec(workload, shape, steps), None, 1).unwrap()
}

/// The mixed-traffic job set: one expensive MHD session (id 0) ahead of
/// `shorts` cheap conv1d sessions (ids 1..).
fn mixed_sessions(long_steps: usize, shorts: usize) -> Vec<Session> {
    let mut v = vec![session(0, "mhd", &[8, 8, 8], long_steps)];
    for id in 1..=shorts {
        v.push(session(id, "conv1d-r3", &[1024], 1));
    }
    v
}

/// Drive a prefilled, already-closed queue on one shard, recording the
/// completion order.
fn drive_prefilled(policy: Policy, sessions: Vec<Session>) -> (Vec<SessionResult>, Vec<usize>) {
    let queue = JobQueue::with_policy(sessions.len(), policy);
    for s in sessions {
        queue.push(s).ok().unwrap();
    }
    queue.close();
    let order = Mutex::new(Vec::new());
    let outcome = drive(&queue, 1, &|ev| {
        if let Event::Done(r) = ev {
            order.lock().unwrap().push(r.id);
        }
    });
    assert!(outcome.failed.is_empty(), "no session may fail here: {:?}", outcome.failed);
    (outcome.results, order.into_inner().unwrap())
}

#[test]
fn cost_aware_pop_order_completes_shorts_before_an_earlier_long_job() {
    // the long job is at the FRONT of the queue in both runs; only the
    // policy differs, so the completion orders witness the scheduler
    let (fifo, fifo_order) = drive_prefilled(Policy::Fifo, mixed_sessions(4, 6));
    let no_preempt = Policy::CostAware { aging_rate: 0.0, preempt: false };
    let (sched, sched_order) = drive_prefilled(no_preempt, mixed_sessions(4, 6));

    assert_eq!(fifo_order, vec![0, 1, 2, 3, 4, 5, 6], "FIFO runs the long job first");
    assert_eq!(
        sched_order.last(),
        Some(&0),
        "cost-aware pop must defer the long job behind every short: {sched_order:?}"
    );
    assert_eq!(sched_order.len(), 7, "every job still completes exactly once");

    // head-of-line fix must not change a single output bit: results are
    // id-sorted, so FIFO and scheduled runs pair up positionally
    assert_eq!(fifo.len(), sched.len());
    for (f, s) in fifo.iter().zip(&sched) {
        assert_eq!(f.id, s.id);
        assert_eq!(f.digest_bits, s.digest_bits, "job {} digest differs across policies", f.id);
        assert_eq!(f.preemptions, 0, "FIFO never preempts");
        assert_eq!(s.preemptions, 0, "nothing arrived mid-run, so nothing preempted");
    }
}

#[test]
fn shorts_arriving_mid_long_session_preempt_it_and_finish_first() {
    // FIFO reference digests for the same specs
    let (fifo, _) = drive_prefilled(Policy::Fifo, mixed_sessions(600, 6));

    let queue = JobQueue::with_policy(8, Policy::cost_aware());
    queue.push(session(0, "mhd", &[8, 8, 8], 600)).ok().unwrap();
    let order = Mutex::new(Vec::new());
    let long_started = AtomicBool::new(false);
    let results = std::thread::scope(|scope| {
        let (queue, order, long_started) = (&queue, &order, &long_started);
        let driver = scope.spawn(move || {
            drive(queue, 1, &|ev| match ev {
                Event::Started { id: 0, .. } => long_started.store(true, Ordering::Release),
                Event::Done(r) => order.lock().unwrap().push(r.id),
                _ => {}
            })
        });
        // submit the shorts only once the long session is mid-run, so
        // completing first REQUIRES step-granularity preemption
        while !long_started.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        for id in 1..=6 {
            queue.push(session(id, "conv1d-r3", &[1024], 1)).ok().unwrap();
        }
        queue.close();
        driver.join().unwrap().results
    });

    let order = order.into_inner().unwrap();
    assert_eq!(order.len(), 7);
    assert_eq!(
        order.last(),
        Some(&0),
        "shorts must interleave into the running long session: {order:?}"
    );
    let long = results.iter().find(|r| r.id == 0).unwrap();
    assert!(long.preemptions >= 1, "the long session must have been parked at least once");

    // preemption pauses the instance between steps — it must not change
    // any session's bits relative to the FIFO reference
    assert_eq!(results.len(), fifo.len());
    for (s, f) in results.iter().zip(&fifo) {
        assert_eq!(s.id, f.id);
        assert_eq!(s.digest_bits, f.digest_bits, "job {} digest changed under preemption", s.id);
    }
}

#[test]
fn daemon_rejects_unmeetable_deadlines_with_predicted_wait() {
    let mut script = String::new();
    // id 0: a long job with no deadline fills the backlog
    script.push_str(&(spec("mhd", &[8, 8, 8], 60).to_json().to_string_compact() + "\n"));
    // id 1: a deadline no backlog state could meet
    let mut doomed = spec("conv1d-r3", &[1024], 1);
    doomed.deadline_s = Some(1e-9);
    script.push_str(&(doomed.to_json().to_string_compact() + "\n"));
    // id 2: the same job with a generous deadline is admitted
    let mut relaxed = spec("conv1d-r3", &[1024], 1);
    relaxed.deadline_s = Some(1e6);
    script.push_str(&(relaxed.to_json().to_string_compact() + "\n"));

    let opts = DaemonOpts { shards: 1, queue_cap: 8, ..DaemonOpts::default() };
    let (report, lines) = server::serve_script(&script, &opts).unwrap();
    assert_eq!(report.results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    assert_eq!(report.rejected.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    assert!(report.rejected[0].error.contains("deadline_s"), "{:?}", report.rejected[0]);

    let events: Vec<Event> = lines.iter().map(|l| Event::parse_line(l).unwrap()).collect();
    let mut saw_rejection = false;
    for ev in &events {
        match ev {
            Event::Rejected { id, error, predicted_wait_s } => {
                assert_eq!(*id, 1);
                let wait = predicted_wait_s.expect("deadline rejection must carry the estimate");
                assert!(wait >= 0.0, "predicted_wait_s={wait}");
                assert!(error.contains("cannot be met"), "{error}");
                saw_rejection = true;
            }
            Event::Accepted { id, predicted_cost_s, .. } => {
                assert!(*predicted_cost_s > 0.0, "job {id} must be priced at admission");
            }
            _ => {}
        }
    }
    assert!(saw_rejection, "no rejected event in {lines:?}");
}

#[test]
fn zero_queue_cap_is_a_configuration_error() {
    let opts = DaemonOpts { queue_cap: 0, ..DaemonOpts::default() };
    let err = server::serve_script("{\"type\":\"drain\"}\n", &opts).unwrap_err();
    assert!(format!("{err:#}").contains("--queue-cap"), "{err:#}");
}
