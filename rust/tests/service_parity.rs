//! Tentpole regressions for the sharded pool + batched job service
//! (ISSUE 4): (1) two OS threads dispatching `pool().run` concurrently
//! must BOTH execute multi-threaded — the old single-gate pool silently
//! collapsed the second dispatch to inline serial; (2) a service session's
//! stepped result must be bit-identical to the same workload stepped
//! directly through `Diffusion::step_into_plan`-family APIs.

use std::collections::HashSet;
use std::sync::{Barrier, Mutex};

use stencilax::coordinator::service::{self, JobSpec};
use stencilax::stencil::diffusion::Diffusion;
use stencilax::stencil::exec::DoubleBuffer;
use stencilax::stencil::grid::{Boundary, Grid};
use stencilax::stencil::plan::LaunchPlan;
use stencilax::util::par;

/// The tests in this binary share the process-wide pool, and the
/// concurrency regression needs two shards free at the same instant —
/// serialize them so a sibling test's bound drivers can't occupy shards
/// mid-assertion.
static SERIAL: Mutex<()> = Mutex::new(());

#[test]
fn concurrent_global_dispatches_both_run_multithreaded() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    if par::pool().shards() < 2 {
        // STENCILAX_SHARDS=1 makes collapse the configured behavior;
        // the regression is only meaningful with >= 2 shards
        eprintln!("skipping: pool has {} shard(s)", par::pool().shards());
        return;
    }
    // Pin the regression on the *global* pool, exactly as the engine hot
    // paths reach it. Per-item sleeps keep both dispatches in flight long
    // enough that the parked workers of each shard provably join.
    let go = Barrier::new(2);
    let run_one = || {
        let ids = Mutex::new(HashSet::new());
        go.wait();
        let parts = par::pool().run(32, 4, &|_i| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        (parts, ids.into_inner().unwrap().len())
    };
    let (a, b) = std::thread::scope(|s| {
        let ha = s.spawn(|| run_one());
        let hb = s.spawn(|| run_one());
        (ha.join().unwrap(), hb.join().unwrap())
    });
    for (tag, (parts, distinct)) in [("first", a), ("second", b)] {
        assert!(
            parts > 1,
            "{tag} concurrent dispatch planned {parts} participant(s) — \
the old gate fallback made it serial"
        );
        assert!(
            distinct > 1,
            "{tag} concurrent dispatch executed on {distinct} thread(s) — \
the old gate fallback made it serial"
        );
    }
}

#[test]
fn service_session_is_bit_identical_to_direct_stepping() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let (n, steps) = (40usize, 5usize);
    let jobs = vec![JobSpec {
        workload: "diffusion2d".into(),
        shape: vec![n, n],
        steps,
        ..JobSpec::default()
    }];
    let report = service::run_jobs(&jobs, 2, None, true).unwrap();
    assert_eq!(report.results.len(), 1);
    let served = &report.results[0];

    // The direct path: the same instance construction the service's
    // native_at performs (seed pattern included), stepped through the
    // public plan-honoring stepper under the very plan the service
    // resolved at admission.
    let plan = LaunchPlan::default_for(&[n, n], report.threads_per_shard);
    assert_eq!(served.plan, plan.describe(), "service must run the admission-resolved plan");
    let mut field = DoubleBuffer::new(Grid::from_fn(&[n, n], 3, |i, j, k| {
        ((i * 31 + j * 17 + k * 7) % 13) as f64
    }));
    let d = Diffusion::new(3, 1.0, 1.0, Boundary::Periodic);
    let dt = d.stable_dt(2);
    for _ in 0..steps {
        d.step_buffered_plan(&plan, &mut field, 2, dt);
    }
    let direct = service::fnv_bits(&field.cur().interior_to_vec());
    assert_eq!(
        served.digest_bits, direct,
        "service-stepped field diverged bitwise from direct stepping"
    );
}

#[test]
fn service_saturates_past_its_shard_count_without_loss() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // more jobs than shards: the queue drains work-conservingly and every
    // job still completes exactly once
    let jobs: Vec<JobSpec> = (0..5)
        .map(|_| JobSpec {
            workload: "diffusion2d".into(),
            shape: vec![20, 20],
            steps: 2,
            ..JobSpec::default()
        })
        .collect();
    let report = service::run_jobs(&jobs, 2, None, true).unwrap();
    assert_eq!(report.results.len(), 5);
    let ids: Vec<usize> = report.results.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    let shards_used: HashSet<usize> = report.results.iter().map(|r| r.shard).collect();
    assert!(!shards_used.is_empty() && shards_used.len() <= report.shards);
    // identical specs: every session's result is bit-identical
    let digests: HashSet<u64> = report.results.iter().map(|r| r.digest_bits).collect();
    assert_eq!(digests.len(), 1, "identical jobs must produce identical bits on every shard");
}
