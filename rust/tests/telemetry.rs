//! Telemetry-layer regressions (ISSUE 10): the Chrome trace export must
//! be well-formed with strictly-nested duration events per track, a
//! live daemon's `stats` snapshot must reconcile with its final report,
//! the per-step bytes/FLOP budgets must be bit-identical across runs,
//! and — the acceptance pin — turning telemetry on must not move a
//! single digest bit.
//!
//! The ring's allocation-free pin lives in `telemetry_alloc.rs` (its
//! counting `#[global_allocator]` needs a binary to itself).

use std::collections::HashMap;
use std::time::Duration;

use stencilax::coordinator::daemon::{client, server, DaemonOpts};
use stencilax::coordinator::service::{self, JobSpec, LoadedJobs};
use stencilax::util::json::Json;
use stencilax::util::telemetry::{Telemetry, TRACE_SCHEMA};

fn job(workload: &str, shape: &[usize], steps: usize) -> JobSpec {
    JobSpec { workload: workload.into(), shape: shape.to_vec(), steps, ..JobSpec::default() }
}

fn loaded(jobs: Vec<JobSpec>) -> LoadedJobs {
    LoadedJobs { jobs: jobs.into_iter().enumerate().collect(), rejected: Vec::new() }
}

/// Walk one track's `ph:"X"` events with a stack: each new span must
/// either start after the current innermost span ends (pop) or end
/// within it (push). Partial overlap on a track is a broken trace —
/// Perfetto renders it as garbage.
fn assert_strictly_nested(tid: f64, events: &[(f64, f64)]) {
    let mut stack: Vec<f64> = Vec::new(); // end timestamps, innermost last
    for &(ts, dur) in events {
        let end = ts + dur;
        while let Some(&top) = stack.last() {
            if ts >= top {
                stack.pop();
            } else {
                assert!(
                    end <= top,
                    "track {tid}: span [{ts}, {end}] partially overlaps enclosing end {top}"
                );
                break;
            }
        }
        stack.push(end);
    }
}

#[test]
fn chrome_trace_export_is_well_formed_and_nested() {
    let jobs = loaded(vec![
        job("diffusion2d", &[24, 24], 3),
        job("conv1d-r3", &[2048], 2),
        job("diffusion1d", &[512], 3),
        job("mhd", &[8, 8, 8], 2),
    ]);
    let tel = Telemetry::new(2);
    let report = service::run_loaded_observed(&jobs, 2, None, true, Some(&tel)).unwrap();
    assert_eq!(report.results.len(), 4);
    assert!(tel.spans_recorded() > 0, "observed serving recorded no spans");

    let path = std::env::temp_dir().join(format!("stencilax_trace_{}.json", std::process::id()));
    tel.write_chrome_trace(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let doc = Json::parse(&text).expect("trace must be valid JSON");

    assert_eq!(doc.req("otherData").unwrap().req_str("schema").unwrap(), TRACE_SCHEMA);
    assert_eq!(doc.req("otherData").unwrap().req_u64("shards").unwrap(), 2);
    let events = doc.req_arr("traceEvents").unwrap();
    assert!(!events.is_empty());

    // every event is well-formed; collect "X" durations per track and
    // check the metadata names cover shard 0, shard 1, and control
    let mut x_by_tid: HashMap<u64, Vec<(f64, f64)>> = HashMap::new();
    let mut names = Vec::new();
    let mut async_begins = 0usize;
    let mut async_ends = 0usize;
    for ev in events {
        let ph = ev.req_str("ph").unwrap();
        let tid = ev.req_u64("tid").unwrap();
        assert!(tid <= 2, "tracks are shard 0, shard 1, control=2; got {tid}");
        match ph {
            "M" => names.push(ev.req("args").unwrap().req_str("name").unwrap().to_string()),
            "X" => {
                let ts = ev.req_f64("ts").unwrap();
                let dur = ev.req_f64("dur").unwrap();
                assert!(ts >= 0.0 && dur >= 0.0);
                x_by_tid.entry(tid).or_default().push((ts, dur));
            }
            "b" => async_begins += 1,
            "e" => async_ends += 1,
            "i" => {
                assert_eq!(ev.req_str("s").unwrap(), "t");
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    names.sort();
    assert_eq!(names, vec!["control", "shard 0", "shard 1"]);
    assert_eq!(async_begins, async_ends, "async b/e events must pair up");
    assert!(async_begins >= 4, "each admitted job opens an Admit async span");
    assert!(x_by_tid.contains_key(&0) || x_by_tid.contains_key(&1), "no shard-track spans");
    for (tid, mut spans) in x_by_tid {
        spans.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_strictly_nested(tid as f64, &spans);
    }
}

#[test]
fn live_daemon_stats_reconcile_with_the_final_report() {
    let socket = std::env::temp_dir().join(format!("stencilax_tel_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&socket);
    let server_path = socket.clone();
    let opts = DaemonOpts { shards: 2, queue_cap: 8, ..DaemonOpts::default() };
    let server = std::thread::spawn(move || server::serve_socket(&server_path, &opts));

    // round 1: submit and wait for all terminal events, daemon stays up
    let lines: Vec<String> = [
        job("diffusion2d", &[16, 16], 2),
        job("diffusion1d", &[256], 3),
        job("no-such-workload", &[8], 1),
    ]
    .iter()
    .map(|j| j.to_json().to_string_compact())
    .collect();
    let patience = Duration::from_secs(5);
    let summary = client::submit_lines(&socket, &lines, false, patience, |_, _| {}).unwrap();
    assert_eq!(summary.outcome.done.len(), 2);
    assert_eq!(summary.outcome.rejected.len(), 1);

    // live snapshot: everything above must already be visible
    let stats = client::fetch_stats(&socket, patience).unwrap();
    assert_eq!(stats.req_str("schema").unwrap(), "stencilax-stats/1");
    assert_eq!(stats.req_u64("jobs_submitted").unwrap(), 3);
    let counters = stats.req("counters").unwrap();
    assert_eq!(counters.req_u64("accepted").unwrap(), 2);
    assert_eq!(counters.req_u64("rejected").unwrap(), 1);
    assert_eq!(counters.req_u64("completed").unwrap(), 2);
    assert_eq!(counters.req_u64("failed").unwrap(), 0);
    assert_eq!(stats.req("queue").unwrap().req_u64("depth").unwrap(), 0, "drained");
    assert!(stats.req_f64("uptime_s").unwrap() > 0.0);
    assert!(stats.req_u64("spans_recorded").unwrap() > 0);
    let shard_rows = stats.req_arr("shards").unwrap();
    assert_eq!(shard_rows.len(), 2);
    for row in shard_rows {
        assert!(row.req_f64("busy_s").unwrap() >= 0.0);
        assert!(row.req_f64("busy_frac").unwrap() >= 0.0);
    }

    // round 2: shutdown; the report must agree with the live snapshot
    let fin = client::submit_lines(&socket, &[], true, patience, |_, _| {}).unwrap();
    let report_json = fin.outcome.report.expect("shutdown returns the final report");
    let report = server.join().unwrap().unwrap();
    assert_eq!(report.results.len(), 2);
    assert_eq!(report.rejected.len(), 1);
    assert_eq!(
        report_json.req_arr("sessions").unwrap().len() as u64,
        counters.req_u64("completed").unwrap(),
        "live completed counter must match the report's session count"
    );
    // per-session telemetry rode the wire: budgets and achieved rates
    for r in &report.results {
        assert!(r.bytes_per_step > 0.0 && r.flops_per_step > 0.0);
        assert!(r.gb_per_s.is_finite() && r.gb_per_s > 0.0);
        assert!(r.roofline_frac.is_finite() && r.roofline_frac > 0.0);
        assert!(r.busy_s > 0.0 && r.busy_s <= r.latency_s);
        assert!(r.queue_wait_s >= 0.0);
    }
    assert!(report_json.req_f64("aggregate_gb_per_s").unwrap() > 0.0);
}

#[test]
fn budgets_are_deterministic_and_telemetry_leaves_digests_untouched() {
    let jobs = vec![job("diffusion2d", &[20, 20], 3), job("mhd", &[8, 8, 8], 2)];

    // plain run twice: the admission-stamped budgets are pure functions
    // of (workload, shape, plan, model) — bit-identical, not just close
    let a = service::run_jobs(&jobs, 2, None, true).unwrap();
    let b = service::run_jobs(&jobs, 2, None, true).unwrap();
    for (ra, rb) in a.results.iter().zip(&b.results) {
        assert_eq!(ra.bytes_per_step.to_bits(), rb.bytes_per_step.to_bits());
        assert_eq!(ra.flops_per_step.to_bits(), rb.flops_per_step.to_bits());
        assert_eq!(ra.digest_bits, rb.digest_bits);
        // achieved rates are budget / time: positive and finite always,
        // equal-to-the-bit only if the timer cooperates (it won't)
        assert!(ra.gb_per_s > 0.0 && ra.gb_per_s.is_finite());
        assert!(ra.gflop_per_s > 0.0 && ra.gflop_per_s.is_finite());
        assert!(ra.roofline_frac > 0.0 && ra.roofline_frac.is_finite());
    }

    // observed run: every telemetry hook armed, digests must not move
    let tel = Telemetry::new(2);
    let c = service::run_loaded_observed(&loaded(jobs), 2, None, true, Some(&tel)).unwrap();
    assert!(tel.spans_recorded() > 0);
    for (ra, rc) in a.results.iter().zip(&c.results) {
        assert_eq!(
            ra.digest_bits, rc.digest_bits,
            "telemetry must be observation-only: digest moved for job {}",
            ra.id
        );
        assert_eq!(ra.bytes_per_step.to_bits(), rc.bytes_per_step.to_bits());
    }
}
