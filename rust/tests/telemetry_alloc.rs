//! The telemetry hot path must be allocation-free (ISSUE 10): span
//! recording is four relaxed/release stores into preallocated ring
//! slots, a counter bump is one `fetch_add`, and busy accounting is one
//! more — wrapping the ring twice over must not touch the heap at all.
//!
//! This lives in its own test binary (like `alloc_free.rs`) because the
//! counting `#[global_allocator]` is process-wide: sibling tests running
//! on other threads would otherwise bleed their allocations into the
//! measured window. One binary, one test, one thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use stencilax::util::telemetry::{Counters, SpanKind, Telemetry, RING_SPANS};

struct Counting;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

#[test]
fn span_rings_wrap_without_allocating() {
    let tel = Telemetry::new(2); // rings preallocate here, before the count
    // warmup: one of each hook, letting any lazy clock init happen first
    let t0 = tel.now_us();
    tel.span_since(0, SpanKind::Chunk, 0, t0);
    tel.instant(0, SpanKind::Fault, 0);
    Counters::bump(&tel.counters.completed);

    // record 3x the ring capacity on every track (shard 0, shard 1,
    // control): each ring wraps twice over inside the measured window
    let before = ALLOCS.load(Ordering::Relaxed);
    for track in 0..3 {
        for i in 0..3 * RING_SPANS {
            tel.span_since(track, SpanKind::Chunk, i, t0);
        }
    }
    for _ in 0..1000 {
        Counters::bump(&tel.counters.accepted);
        tel.add_busy(1, 1e-6);
    }
    let during = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(during, 0, "recording spans/counters allocated {during} times");

    // the rings kept exact totals through the wrap and retained the
    // most-recent window (capacity per track, not everything recorded)
    assert_eq!(tel.spans_recorded(), (3 * 3 * RING_SPANS + 2) as u64);
    let spans = tel.snapshot_spans(); // reading may allocate — into this Vec
    assert!(spans.len() >= RING_SPANS, "retained window vanished: {}", spans.len());
    assert!(spans.len() <= 3 * RING_SPANS + 2, "retained more than capacity");
    assert_eq!(tel.counters.accepted.load(Ordering::Relaxed), 1000);
    assert!((tel.busy_s(1) - 1e-3).abs() < 1e-9);
}
