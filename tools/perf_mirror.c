/* Structural perf mirror of rust/src/stencil before/after ISSUE 2.
 *
 * "before" mirrors the seed engine: z-plane-only parallelism (serial when
 * nz == 1), per-plane/per-row heap allocation, scatter through idx()
 * multiplications, ~38 materialized intermediate grids per MHD substep,
 * separate phi and RK3 passes.
 * "after" mirrors the fused exec layer: (j,k) row-blocked parallelism,
 * reusable per-thread workspaces, direct row writes, single fused sweep.
 *
 * gcc -O3 -march=native -pthread perf_mirror.c -o perf_mirror -lm
 */
#define _GNU_SOURCE
#include <math.h>
#include <pthread.h>
#include <stdatomic.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

/* ---------------- parallel_for (scoped threads + atomic counter) ------- */
typedef void (*item_fn)(int i, void *ctx);
typedef struct {
    atomic_int next;
    int n;
    item_fn f;
    void *ctx;
} pf_t;

static void *pf_worker(void *arg) {
    pf_t *p = (pf_t *)arg;
    for (;;) {
        int i = atomic_fetch_add(&p->next, 1);
        if (i >= p->n) break;
        p->f(i, p->ctx);
    }
    return NULL;
}

static void parallel_for(int n, int threads, item_fn f, void *ctx) {
    pf_t p;
    atomic_init(&p.next, 0);
    p.n = n; p.f = f; p.ctx = ctx;
    if (threads <= 1 || n <= 1) { for (int i = 0; i < n; i++) f(i, ctx); return; }
    pthread_t th[16];
    int nw = threads - 1; if (nw > 16) nw = 16;
    for (int w = 0; w < nw; w++) pthread_create(&th[w], NULL, pf_worker, &p);
    pf_worker(&p);
    for (int w = 0; w < nw; w++) pthread_join(th[w], NULL);
}

/* ---------------- grid helpers ---------------------------------------- */
#define R 3
static int NX, NY, NZ, PX, PY, PZ;
#define IDX(i, j, k) ((i) + R + PX * ((j) + R + PY * ((k) + R)))
#define PIDX(pi, pj, pk) ((pi) + PX * ((pj) + PY * (pk)))
static size_t PADDED;

static const double C1[7] = {-1.0 / 60, 3.0 / 20, -3.0 / 4, 0.0, 3.0 / 4, -3.0 / 20, 1.0 / 60};
static const double C2[7] = {1.0 / 90, -3.0 / 20, 1.5, -49.0 / 18, 1.5, -3.0 / 20, 1.0 / 90};

static void fill_ghosts(double *d) {
    for (int pk = 0; pk < PZ; pk++) {
        int ki = pk >= R && pk < R + NZ;
        for (int pj = 0; pj < PY; pj++) {
            int ji = pj >= R && pj < R + NY;
            if (ki && ji) {
                for (int pi = 0; pi < R; pi++) {
                    int wi = (pi - R + 4 * NX) % NX, wj = (pj - R + 4 * NY) % NY, wk = (pk - R + 4 * NZ) % NZ;
                    d[PIDX(pi, pj, pk)] = d[IDX(wi, wj, wk)];
                }
                for (int pi = PX - R; pi < PX; pi++) {
                    int wi = (pi - R + 4 * NX) % NX, wj = (pj - R + 4 * NY) % NY, wk = (pk - R + 4 * NZ) % NZ;
                    d[PIDX(pi, pj, pk)] = d[IDX(wi, wj, wk)];
                }
            } else {
                for (int pi = 0; pi < PX; pi++) {
                    int wi = (pi - R + 4 * NX) % NX, wj = (pj - R + 4 * NY) % NY, wk = (pk - R + 4 * NZ) % NZ;
                    d[PIDX(pi, pj, pk)] = d[IDX(wi, wj, wk)];
                }
            }
        }
    }
}

/* =================== 2-D diffusion ===================================== */
/* BEFORE: clone + ghost fill on clone; z-plane par_map over nz==1 (serial);
 * per-plane malloc, per-row lap malloc, scatter via IDX() per element. */
static double dif_s;
static void diffusion2d_before(double **field) {
    double *src = malloc(PADDED * sizeof(double));
    memcpy(src, *field, PADDED * sizeof(double)); /* the retired clone */
    fill_ghosts(src);
    double *out = calloc(PADDED, sizeof(double));
    /* nz == 1: the old engine's par_map(nz, ..) collapses to serial */
    {
        double *plane = malloc((size_t)NX * NY * sizeof(double));
        for (int j = 0; j < NY; j++) {
            int base = IDX(0, j, 0);
            double *row = plane + (size_t)j * NX;
            memcpy(row, src + base, NX * sizeof(double));
            double *lap = calloc(NX, sizeof(double)); /* per-row alloc! */
            for (int axis = 0; axis < 2; axis++) {
                int st = axis == 0 ? 1 : PX;
                for (int t = 0; t < 7; t++) {
                    double c = C2[t];
                    if (c == 0.0) continue;
                    const double *sr = src + base + (t - R) * st;
                    for (int i = 0; i < NX; i++) lap[i] += c * sr[i];
                }
            }
            for (int i = 0; i < NX; i++) row[i] += dif_s * lap[i];
            free(lap);
        }
        for (int j = 0; j < NY; j++)
            for (int i = 0; i < NX; i++) out[IDX(i, j, 0)] = plane[(size_t)j * NX + i];
        free(plane);
    }
    free(src);
    free(*field);
    *field = out;
}

/* AFTER: in-place ghost fill, (j,k) row blocks, per-thread reused lap,
 * direct row writes into the spare buffer. */
typedef struct { double *src, *dst, **lap; int per, rows; } dif_ctx;
static void diffusion2d_after_block(int b, void *cv) {
    dif_ctx *c = (dif_ctx *)cv;
    /* per-thread workspace: index by a cheap thread hash (block id works
     * because blocks are handed to whichever thread steals them; use
     * thread-local storage instead) */
    static __thread double *lap = NULL;
    if (!lap) lap = malloc(NX * sizeof(double));
    int lo = b * c->per, hi = lo + c->per;
    if (hi > c->rows) hi = c->rows;
    for (int j = lo; j < hi; j++) {
        int base = IDX(0, j, 0);
        double *row = c->dst + base;
        memcpy(row, c->src + base, NX * sizeof(double));
        memset(lap, 0, NX * sizeof(double));
        for (int axis = 0; axis < 2; axis++) {
            int st = axis == 0 ? 1 : PX;
            for (int t = 0; t < 7; t++) {
                double cc = C2[t];
                if (cc == 0.0) continue;
                const double *sr = c->src + base + (t - R) * st;
                for (int i = 0; i < NX; i++) lap[i] += cc * sr[i];
            }
        }
        for (int i = 0; i < NX; i++) row[i] += dif_s * lap[i];
    }
}

static void diffusion2d_after(double **cur, double **next, int threads) {
    fill_ghosts(*cur);
    int rows = NY;
    int per = (rows + threads * 4 - 1) / (threads * 4);
    int nblocks = (rows + per - 1) / per;
    dif_ctx c = {*cur, *next, NULL, per, rows};
    parallel_for(nblocks, threads, diffusion2d_after_block, &c);
    double *t = *cur; *cur = *next; *next = t;
}

/* =================== MHD =============================================== */
#define NF 8
static const double ALPHA[3] = {0.0, -5.0 / 9.0, -153.0 / 128.0};
static const double BETA[3] = {1.0 / 3.0, 15.0 / 16.0, 8.0 / 15.0};
static double cs0 = 1.0, gam = 5.0 / 3.0, cp_ = 1.0, rho0 = 1.0, nu_v = 5e-3,
              eta_v = 5e-3, zeta_v = 0.0, mu0_v = 1.0, kappa_v = 1e-3, inv_dx = 1.0;

/* phi: the nonlinear pointwise map (A1-A4), shared by both paths.
 * vals layout matches fused.rs: 0-2 glnrho, 3-5 gss, 6 lap_lnrho,
 * 7 lap_ss, 8-16 du, 17-19 lap_u, 20-22 gdivu, 23-31 da, 32-34 lap_a,
 * 35-37 gdiva. */
static inline void phi(const double *v, const double *sv, double *cell) {
    double lnrho = sv[0], ss = sv[4];
    const double *u = sv + 1;
    double divu = v[8] + v[12] + v[16];
    double rho = exp(lnrho), inv_rho = exp(-lnrho);
    double exparg = gam * ss / cp_ + (gam - 1.0) * (lnrho - log(rho0));
    double cs2 = cs0 * cs0 * exp(exparg), temp = (cs0 * cs0 / (cp_ * (gam - 1.0))) * exp(exparg);
    double bb[3] = {v[23 + 7] - v[23 + 5], v[23 + 2] - v[23 + 6], v[23 + 3] - v[23 + 1]};
    double jv[3], jxb[3], uxb[3];
    for (int a = 0; a < 3; a++) jv[a] = (v[35 + a] - v[32 + a]) / mu0_v;
    jxb[0] = jv[1] * bb[2] - jv[2] * bb[1]; jxb[1] = jv[2] * bb[0] - jv[0] * bb[2];
    jxb[2] = jv[0] * bb[1] - jv[1] * bb[0];
    uxb[0] = u[1] * bb[2] - u[2] * bb[1]; uxb[1] = u[2] * bb[0] - u[0] * bb[2];
    uxb[2] = u[0] * bb[1] - u[1] * bb[0];
    double st[3][3], s2 = 0.0, sgl[3] = {0, 0, 0};
    for (int a = 0; a < 3; a++)
        for (int b = 0; b < 3; b++) {
            st[a][b] = 0.5 * (v[8 + 3 * a + b] + v[8 + 3 * b + a]);
            if (a == b) st[a][b] -= divu / 3.0;
        }
    for (int a = 0; a < 3; a++)
        for (int b = 0; b < 3; b++) { s2 += st[a][b] * st[a][b]; sgl[a] += st[a][b] * v[b]; }
    cell[0] = -(u[0] * v[0] + u[1] * v[1] + u[2] * v[2]) - divu;
    for (int a = 0; a < 3; a++) {
        double adv = -(u[0] * v[8 + 3 * a] + u[1] * v[8 + 3 * a + 1] + u[2] * v[8 + 3 * a + 2]);
        double press = -cs2 * (v[3 + a] / cp_ + v[a]);
        double visc = nu_v * (v[17 + a] + v[20 + a] / 3.0 + 2.0 * sgl[a]) + zeta_v * v[20 + a];
        cell[1 + a] = adv + press + jxb[a] * inv_rho + visc;
    }
    double glnt[3], lap_lnt = gam / cp_ * v[7] + (gam - 1.0) * v[6];
    for (int a = 0; a < 3; a++) glnt[a] = gam / cp_ * v[3 + a] + (gam - 1.0) * v[a];
    double dkg = kappa_v * temp * (lap_lnt + glnt[0] * glnt[0] + glnt[1] * glnt[1] + glnt[2] * glnt[2]);
    double j2 = jv[0] * jv[0] + jv[1] * jv[1] + jv[2] * jv[2];
    double heat = dkg + eta_v * mu0_v * j2 + 2.0 * rho * nu_v * s2 + zeta_v * rho * divu * divu;
    cell[4] = -(u[0] * v[3] + u[1] * v[4] + u[2] * v[5]) + heat * inv_rho / temp;
    for (int a = 0; a < 3; a++) cell[5 + a] = uxb[a] + eta_v * v[32 + a];
}

/* ---- BEFORE: apply_axis materializing grids, z-plane parallel --------- */
typedef struct { const double *src; double *out; const double *w; int st; double scale; } ax_ctx;
static void apply_axis_plane(int k, void *cv) {
    ax_ctx *c = (ax_ctx *)cv;
    double *plane = malloc((size_t)NX * NY * sizeof(double)); /* per-plane alloc */
    memset(plane, 0, (size_t)NX * NY * sizeof(double));
    for (int j = 0; j < NY; j++) {
        int base = IDX(0, j, k);
        double *dst = plane + (size_t)j * NX;
        for (int t = 0; t < 7; t++) {
            double cc = c->w[t];
            if (cc == 0.0) continue;
            const double *sr = c->src + base + (t - R) * c->st;
            for (int i = 0; i < NX; i++) dst[i] += cc * sr[i];
        }
        for (int i = 0; i < NX; i++) dst[i] *= c->scale;
    }
    for (int j = 0; j < NY; j++)   /* scatter via idx() per element */
        for (int i = 0; i < NX; i++) c->out[IDX(i, j, k)] = plane[(size_t)j * NX + i];
    free(plane);
}

static int g_threads = 2;
static double *apply_axis_before(const double *src, int axis, const double *w, double scale) {
    double *out = calloc(PADDED, sizeof(double));
    int st = axis == 0 ? 1 : (axis == 1 ? PX : PX * PY);
    ax_ctx c = {src, out, w, st, scale};
    parallel_for(NZ, g_threads, apply_axis_plane, &c);
    return out;
}

static void add_assign_before(double *a, const double *b) {
    for (int k = 0; k < NZ; k++)       /* elementwise get/set with idx mults */
        for (int j = 0; j < NY; j++)
            for (int i = 0; i < NX; i++) a[IDX(i, j, k)] += b[IDX(i, j, k)];
}

static double *lap_before(const double *src) {
    double *acc = apply_axis_before(src, 0, C2, inv_dx * inv_dx);
    for (int ax = 1; ax < 3; ax++) {
        double *t = apply_axis_before(src, ax, C2, inv_dx * inv_dx);
        add_assign_before(acc, t);
        free(t);
    }
    return acc;
}

static double *d1d1_before(const double *src, int a1, int a2) {
    double *mid = apply_axis_before(src, a1, C1, inv_dx);
    fill_ghosts(mid);
    double *out = apply_axis_before(mid, a2, C1, inv_dx);
    free(mid);
    return out;
}

typedef struct { double **deriv; double **state; double **rhs; } phi_ctx;
static void phi_plane_before(int k, void *cv) {
    phi_ctx *c = (phi_ctx *)cv;
    double *plane = malloc((size_t)NX * NY * NF * sizeof(double)); /* per-plane */
    for (int j = 0; j < NY; j++)
        for (int i = 0; i < NX; i++) {
            double vals[38], sv[NF], cell[NF];
            for (int v = 0; v < 38; v++) vals[v] = c->deriv[v][IDX(i, j, k)]; /* gathers */
            for (int f = 0; f < NF; f++) sv[f] = c->state[f][IDX(i, j, k)];
            phi(vals, sv, cell);
            memcpy(plane + ((size_t)j * NX + i) * NF, cell, NF * sizeof(double));
        }
    for (int j = 0; j < NY; j++)       /* scatter into 8 rhs grids */
        for (int i = 0; i < NX; i++)
            for (int f = 0; f < NF; f++)
                c->rhs[f][IDX(i, j, k)] = plane[((size_t)j * NX + i) * NF + f];
    free(plane);
}

static void mhd_substep_before(double **state, double **w, int l, double dt) {
    for (int f = 0; f < NF; f++) fill_ghosts(state[f]);
    double *deriv[38];
    int d = 0;
    /* glnrho, gss */
    for (int ax = 0; ax < 3; ax++) deriv[d++] = apply_axis_before(state[0], ax, C1, inv_dx);
    for (int ax = 0; ax < 3; ax++) deriv[d++] = apply_axis_before(state[4], ax, C1, inv_dx);
    deriv[d++] = lap_before(state[0]);
    deriv[d++] = lap_before(state[4]);
    for (int a = 0; a < 3; a++)
        for (int b = 0; b < 3; b++) deriv[d++] = apply_axis_before(state[1 + a], b, C1, inv_dx);
    for (int a = 0; a < 3; a++) deriv[d++] = lap_before(state[1 + a]);
    for (int i = 0; i < 3; i++) { /* gdivu */
        double *acc = calloc(PADDED, sizeof(double));
        for (int j = 0; j < 3; j++) {
            double *t = (i == j) ? apply_axis_before(state[1 + j], i, C2, inv_dx * inv_dx)
                                 : d1d1_before(state[1 + j], j, i);
            add_assign_before(acc, t);
            free(t);
        }
        deriv[d++] = acc;
    }
    for (int a = 0; a < 3; a++)
        for (int b = 0; b < 3; b++) deriv[d++] = apply_axis_before(state[5 + a], b, C1, inv_dx);
    for (int a = 0; a < 3; a++) deriv[d++] = lap_before(state[5 + a]);
    for (int i = 0; i < 3; i++) { /* gdiva */
        double *acc = calloc(PADDED, sizeof(double));
        for (int j = 0; j < 3; j++) {
            double *t = (i == j) ? apply_axis_before(state[5 + j], i, C2, inv_dx * inv_dx)
                                 : d1d1_before(state[5 + j], j, i);
            add_assign_before(acc, t);
            free(t);
        }
        deriv[d++] = acc;
    }
    double *rhs[NF];
    for (int f = 0; f < NF; f++) rhs[f] = calloc(PADDED, sizeof(double));
    phi_ctx pc = {deriv, state, rhs};
    parallel_for(NZ, g_threads, phi_plane_before, &pc);
    for (int v = 0; v < 38; v++) free(deriv[v]);
    /* separate RK3 pass, elementwise with idx mults */
    for (int f = 0; f < NF; f++)
        for (int k = 0; k < NZ; k++)
            for (int j = 0; j < NY; j++)
                for (int i = 0; i < NX; i++) {
                    double wv = ALPHA[l] * w[f][IDX(i, j, k)] + dt * rhs[f][IDX(i, j, k)];
                    w[f][IDX(i, j, k)] = wv;
                    state[f][IDX(i, j, k)] += BETA[l] * wv;
                }
    for (int f = 0; f < NF; f++) free(rhs[f]);
}

/* ---- AFTER: fused row sweep ------------------------------------------- */
static void stencil_row_c(double *dst, const double *data, int base, int st, const double *w, double scale) {
    memset(dst, 0, NX * sizeof(double));
    for (int t = 0; t < 7; t++) {
        double c = w[t];
        if (c == 0.0) continue;
        const double *sr = data + base + (t - R) * st;
        for (int i = 0; i < NX; i++) dst[i] += c * sr[i];
    }
    for (int i = 0; i < NX; i++) dst[i] *= scale;
}

static void d1d1_row_c(double *dst, double *tmp, const double *data, int base, int s1, int s2) {
    memset(dst, 0, NX * sizeof(double));
    for (int t2 = 0; t2 < 7; t2++) {
        double cb = C1[t2];
        if (cb == 0.0) continue;
        stencil_row_c(tmp, data, base + (t2 - R) * s2, s1, C1, inv_dx);
        for (int i = 0; i < NX; i++) dst[i] += cb * tmp[i];
    }
    for (int i = 0; i < NX; i++) dst[i] *= inv_dx;
}

static void lap_row_c(double *dst, double *tmp, const double *data, int base) {
    int strides[3] = {1, PX, PX * PY};
    stencil_row_c(dst, data, base, strides[0], C2, inv_dx * inv_dx);
    for (int a = 1; a < 3; a++) {
        stencil_row_c(tmp, data, base, strides[a], C2, inv_dx * inv_dx);
        for (int i = 0; i < NX; i++) dst[i] += tmp[i];
    }
}

typedef struct { double **state; double **w; double **dst; int l; double dt; int per, rows; } fu_ctx;
static void fused_block(int b, void *cv) {
    fu_ctx *c = (fu_ctx *)cv;
    static __thread double *buf = NULL;
    if (!buf) buf = malloc(40 * (size_t)NX * sizeof(double));
    int strides[3] = {1, PX, PX * PY};
    int lo = b * c->per, hi = lo + c->per;
    if (hi > c->rows) hi = c->rows;
    for (int row = lo; row < hi; row++) {
        int j = row % NY, k = row / NY;
        int base = IDX(0, j, k);
        double *tmp = buf + 38 * (size_t)NX, *tmp2 = buf + 39 * (size_t)NX;
#define ROWB(n) (buf + (size_t)(n) * NX)
        for (int ax = 0; ax < 3; ax++) {
            stencil_row_c(ROWB(0 + ax), c->state[0], base, strides[ax], C1, inv_dx);
            stencil_row_c(ROWB(3 + ax), c->state[4], base, strides[ax], C1, inv_dx);
        }
        lap_row_c(ROWB(6), tmp, c->state[0], base);
        lap_row_c(ROWB(7), tmp, c->state[4], base);
        for (int a = 0; a < 3; a++) {
            for (int bb = 0; bb < 3; bb++) {
                stencil_row_c(ROWB(8 + 3 * a + bb), c->state[1 + a], base, strides[bb], C1, inv_dx);
                stencil_row_c(ROWB(23 + 3 * a + bb), c->state[5 + a], base, strides[bb], C1, inv_dx);
            }
            lap_row_c(ROWB(17 + a), tmp, c->state[1 + a], base);
            lap_row_c(ROWB(32 + a), tmp, c->state[5 + a], base);
            /* gdiv u and a */
            for (int which = 0; which < 2; which++) {
                double *dst = ROWB(which ? 35 + a : 20 + a);
                memset(dst, 0, NX * sizeof(double));
                for (int jf = 0; jf < 3; jf++) {
                    const double *fd = c->state[(which ? 5 : 1) + jf];
                    if (jf == a) stencil_row_c(tmp, fd, base, strides[a], C2, inv_dx * inv_dx);
                    else d1d1_row_c(tmp, tmp2, fd, base, strides[jf], strides[a]);
                    for (int i = 0; i < NX; i++) dst[i] += tmp[i];
                }
            }
        }
        for (int i = 0; i < NX; i++) {
            double vals[38], sv[NF], cell[NF];
            for (int v = 0; v < 38; v++) vals[v] = buf[(size_t)v * NX + i];
            for (int f = 0; f < NF; f++) sv[f] = c->state[f][base + i];
            phi(vals, sv, cell);
            for (int f = 0; f < NF; f++) {
                double wv = ALPHA[c->l] * c->w[f][base + i] + c->dt * cell[f];
                c->w[f][base + i] = wv;
                c->dst[f][base + i] = sv[f] + BETA[c->l] * wv;
            }
        }
    }
}

static void mhd_substep_after(double **state, double **w, double **spare, int l, double dt, int threads) {
    for (int f = 0; f < NF; f++) fill_ghosts(state[f]);
    int rows = NY * NZ;
    int per = (rows + threads * 4 - 1) / (threads * 4);
    int nblocks = (rows + per - 1) / per;
    fu_ctx c = {state, w, spare, l, dt, per, rows};
    parallel_for(nblocks, threads, fused_block, &c);
    for (int f = 0; f < NF; f++) { double *t = state[f]; state[f] = spare[f]; spare[f] = t; }
}

/* =================== driver ============================================ */
static double checksum(double **state) {
    double s = 0;
    for (int f = 0; f < NF; f++)
        for (int k = 0; k < NZ; k++)
            for (int j = 0; j < NY; j++)
                for (int i = 0; i < NX; i++) s += state[f][IDX(i, j, k)];
    return s;
}

int main(int argc, char **argv) {
    int threads = argc > 1 ? atoi(argv[1]) : 2;
    g_threads = threads;

    /* ---- 2-D diffusion 4096^2 r=3 ---- */
    NX = 4096; NY = 4096; NZ = 1;
    PX = NX + 2 * R; PY = NY + 2 * R; PZ = NZ + 2 * R;
    PADDED = (size_t)PX * PY * PZ;
    dif_s = 1e-4;
    {
        double *f = calloc(PADDED, sizeof(double));
        for (int j = 0; j < NY; j++)
            for (int i = 0; i < NX; i++) f[IDX(i, j, 0)] = (i * 31 + j * 17) % 13;
        diffusion2d_before(&f); /* warmup */
        double t0 = now_s();
        for (int s = 0; s < 5; s++) diffusion2d_before(&f);
        double tb = (now_s() - t0) / 5;
        free(f);

        double *cur = calloc(PADDED, sizeof(double));
        double *next = calloc(PADDED, sizeof(double));
        for (int j = 0; j < NY; j++)
            for (int i = 0; i < NX; i++) cur[IDX(i, j, 0)] = (i * 31 + j * 17) % 13;
        diffusion2d_after(&cur, &next, threads); /* warmup */
        double t1 = now_s();
        for (int s = 0; s < 5; s++) diffusion2d_after(&cur, &next, threads);
        double ta = (now_s() - t1) / 5;
        printf("diffusion2d 4096^2 r=3  threads=%d: before %.1f ms  after %.1f ms  speedup %.2fx\n",
               threads, tb * 1e3, ta * 1e3, tb / ta);
        free(cur); free(next);
    }

    /* ---- MHD 64^3 r=3, one RK3 step = 3 substeps ---- */
    NX = NY = NZ = 64;
    PX = NX + 2 * R; PY = NY + 2 * R; PZ = NZ + 2 * R;
    PADDED = (size_t)PX * PY * PZ;
    {
        double *sb[NF], *wb[NF], *sa[NF], *wa[NF], *spare[NF];
        for (int f = 0; f < NF; f++) {
            sb[f] = calloc(PADDED, sizeof(double));
            wb[f] = calloc(PADDED, sizeof(double));
            sa[f] = calloc(PADDED, sizeof(double));
            wa[f] = calloc(PADDED, sizeof(double));
            spare[f] = calloc(PADDED, sizeof(double));
            for (int k = 0; k < NZ; k++)
                for (int j = 0; j < NY; j++)
                    for (int i = 0; i < NX; i++) {
                        double v = 1e-2 * (((f * 31 + i * 7 + j * 5 + k * 3) % 13) - 6);
                        sb[f][IDX(i, j, k)] = v;
                        sa[f][IDX(i, j, k)] = v;
                    }
        }
        double dt = 1e-4;
        for (int l = 0; l < 3; l++) mhd_substep_before(sb, wb, l, dt); /* warmup */
        double t0 = now_s();
        for (int s = 0; s < 3; s++)
            for (int l = 0; l < 3; l++) mhd_substep_before(sb, wb, l, dt);
        double tb = (now_s() - t0) / 3;

        for (int l = 0; l < 3; l++) mhd_substep_after(sa, wa, spare, l, dt, threads);
        double t1 = now_s();
        for (int s = 0; s < 3; s++)
            for (int l = 0; l < 3; l++) mhd_substep_after(sa, wa, spare, l, dt, threads);
        double ta = (now_s() - t1) / 3;
        printf("mhd 64^3 rk3 step       threads=%d: before %.1f ms  after %.1f ms  speedup %.2fx\n",
               threads, tb * 1e3, ta * 1e3, tb / ta);
        printf("  parity: |before-after| checksum delta = %.3e (both advanced 12 substeps)\n",
               fabs(checksum(sb) - checksum(sa)));
    }
    return 0;
}
