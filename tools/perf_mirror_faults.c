/* Structural mirror of the PR 7 fault-isolation layer's fault-free path
 * (see rust/src/coordinator/service.rs ActiveSession::step_checked and
 * DESIGN.md §15): a diffusion2d r=3 step followed by the per-step
 * divergence probe — 64 strided interior samples on interior steps, the
 * full field on the final step — plus the retry-recovery arithmetic for
 * an injected fault at mid-session.
 *
 * Measures, per grid size:
 *   - median step time (the baseline the probe rides on)
 *   - sampled probe (64 isfinite checks) and its share of a step
 *   - exhaustive probe (n*n checks) and its share of a step
 *   - recovered-retry latency multiplier for a panic at step s/2 of s
 *     steps with the queue's 5 ms base backoff
 *
 * Build/run: gcc -O3 -march=native -o /tmp/pmf tools/perf_mirror_faults.c -lm && /tmp/pmf
 */
#include <math.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define R 3
#define PROBE_SAMPLES 64
#define RETRY_BACKOFF_BASE_MS 5.0

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double median(double *xs, int n) {
    qsort(xs, n, sizeof(double), cmp_d);
    return n % 2 ? xs[n / 2] : 0.5 * (xs[n / 2 - 1] + xs[n / 2]);
}

/* one r=3 star-stencil step over the n*n interior of a padded field */
static void step(const double *src, double *dst, int n) {
    const int p = n + 2 * R;
    static const double w[2 * R + 1] = {1. / 90, -3. / 20, 3. / 2, -49. / 18,
                                        3. / 2,  -3. / 20, 1. / 90};
    for (int i = R; i < n + R; i++) {
        for (int j = R; j < n + R; j++) {
            double acc = 0.0;
            for (int k = -R; k <= R; k++) {
                acc += w[k + R] * src[i * p + j + k];
                acc += w[k + R] * src[(i + k) * p + j];
            }
            dst[i * p + j] = src[i * p + j] + 1e-3 * acc;
        }
    }
}

/* sampled probe: `samples` strided interior elements, like
 * Workload::probe_finite with probe_slice */
static int probe(const double *f, int n, long samples) {
    const int p = n + 2 * R;
    long total = (long)n * n;
    if (samples > total) samples = total;
    long stride = total / samples;
    if (stride < 1) stride = 1;
    for (long s = 0; s < total; s += stride) {
        int i = (int)(s / n), j = (int)(s % n);
        if (!isfinite(f[(i + R) * p + j + R])) return 0;
    }
    return 1;
}

static void bench(int n, int steps) {
    const int p = n + 2 * R;
    double *a = calloc((size_t)p * p, sizeof(double));
    double *b = calloc((size_t)p * p, sizeof(double));
    for (int i = 0; i < p * p; i++) a[i] = ((i * 31) % 13) * 0.1;

    enum { ITERS = 400 };
    static double ts[ITERS], tp[ITERS], tf[ITERS];
    volatile int ok = 1;
    for (int it = 0; it < ITERS; it++) {
        double t0 = now_s();
        step(a, b, n);
        ts[it] = now_s() - t0;
        t0 = now_s();
        ok &= probe(b, n, PROBE_SAMPLES);
        tp[it] = now_s() - t0;
        t0 = now_s();
        ok &= probe(b, n, (long)n * n);
        tf[it] = now_s() - t0;
        double *t = a; a = b; b = t;
    }
    double ms = median(ts, ITERS), mp = median(tp, ITERS), mf = median(tf, ITERS);
    /* a panic at step steps/2 wastes those steps, backs off, reruns all */
    double clean = steps * (ms + mp) + mf - mp;
    double retried = (steps / 2) * (ms + mp) + RETRY_BACKOFF_BASE_MS * 1e-3 + clean;
    printf("n=%-4d step %10.3f us | probe64 %8.3f us (%5.2f%% of step) | "
           "full probe %8.3f us (%5.2f%% of step) | retry@%d/%d latency x%.2f%s\n",
           n, ms * 1e6, mp * 1e6, 100.0 * mp / ms, mf * 1e6, 100.0 * mf / ms,
           steps / 2, steps, retried / clean, ok ? "" : " (non-finite?!)");
    free(a);
    free(b);
}

int main(void) {
    bench(24, 4);   /* the chaos smoke's diffusion2d size */
    bench(256, 4);  /* a mid-size serving job */
    bench(1024, 4); /* large: probe64 cost should vanish in the noise */
    return 0;
}
