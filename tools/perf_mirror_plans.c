/* Structural perf mirror of ISSUE 3's LaunchPlan search space.
 *
 * Mirrors the native engine's row-blocked diffusion sweep and chunked 1-D
 * cross-correlation, then measures the knobs the empirical tuner
 * (coordinator/empirical.rs) searches: rows-per-block / oversubscription
 * for grid sweeps, chunk length for 1-D sweeps — against the default plan
 * (4 blocks per thread, 8192-element chunks). Numbers feed EXPERIMENTS.md
 * §Perf/L3-9; the Rust engine reproduces the same sweep structure, so the
 * *relative* plan ordering carries over even though absolute times do not.
 *
 * gcc -O3 -march=native -pthread perf_mirror_plans.c -o perf_mirror_plans -lm
 */
#define _GNU_SOURCE
#include <math.h>
#include <pthread.h>
#include <stdatomic.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

/* ---------------- parallel_for (scoped threads + atomic counter) ------- */
typedef void (*item_fn)(int i, void *ctx);
typedef struct {
    atomic_int next;
    int n;
    item_fn f;
    void *ctx;
} pf_t;

static void *pf_worker(void *arg) {
    pf_t *p = (pf_t *)arg;
    for (;;) {
        int i = atomic_fetch_add(&p->next, 1);
        if (i >= p->n) break;
        p->f(i, p->ctx);
    }
    return NULL;
}

static void parallel_for(int n, int threads, item_fn f, void *ctx) {
    pf_t p;
    atomic_init(&p.next, 0);
    p.n = n; p.f = f; p.ctx = ctx;
    if (threads <= 1 || n <= 1) { for (int i = 0; i < n; i++) f(i, ctx); return; }
    pthread_t th[16];
    int nw = threads - 1; if (nw > 16) nw = 16;
    for (int w = 0; w < nw; w++) pthread_create(&th[w], NULL, pf_worker, &p);
    pf_worker(&p);
    for (int w = 0; w < nw; w++) pthread_join(th[w], NULL);
}

/* ---------------- diffusion2d sweep under a row-block plan ------------- */
#define RAD 3
static int N2;              /* interior extent (N2 x N2) */
static int P2;              /* padded extent */
static double *SRC, *DST;
static double C2[2 * RAD + 1];
static int BLK_PER, BLK_N;  /* rows per block, number of blocks */

static void diff2_block(int b, void *ctx) {
    (void)ctx;
    int lo = b * BLK_PER, hi = lo + BLK_PER;
    if (hi > N2) hi = N2;
    double s = 0.1;
    for (int j = lo; j < hi; j++) {
        double *out = DST + (size_t)(j + RAD) * P2 + RAD;
        const double *base = SRC + (size_t)(j + RAD) * P2 + RAD;
        for (int i = 0; i < N2; i++) {
            double lap = 0.0;
            for (int t = 0; t <= 2 * RAD; t++) {
                lap += C2[t] * base[i + t - RAD];          /* x axis */
                lap += C2[t] * base[i + (t - RAD) * P2];   /* y axis */
            }
            out[i] = base[i] + s * lap;
        }
    }
}

static double bench_diff2(int rows_per_block, int threads, int iters) {
    BLK_PER = rows_per_block;
    BLK_N = (N2 + BLK_PER - 1) / BLK_PER;
    /* warm-up */
    parallel_for(BLK_N, threads, diff2_block, NULL);
    double best = 1e30;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        parallel_for(BLK_N, threads, diff2_block, NULL);
        double dt = now_s() - t0;
        if (dt < best) best = dt;
    }
    return best;
}

/* ---------------- xcorr1d under a chunk plan --------------------------- */
static int NX1, RX1;
static double *FPAD, *OUT, TAPS[2 * 64 + 1];
static int CHUNK;

static void xcorr_chunk(int c, void *ctx) {
    (void)ctx;
    int lo = c * CHUNK, hi = lo + CHUNK;
    if (hi > NX1) hi = NX1;
    memset(OUT + lo, 0, (size_t)(hi - lo) * sizeof(double));
    for (int t = 0; t <= 2 * RX1; t++) {
        double g = TAPS[t];
        const double *src = FPAD + lo + t;
        for (int i = lo; i < hi; i++) OUT[i] += g * src[i - lo];
    }
}

static double bench_xcorr(int chunk, int threads, int iters) {
    CHUNK = chunk;
    int nchunks = (NX1 + CHUNK - 1) / CHUNK;
    parallel_for(nchunks, threads, xcorr_chunk, NULL);
    double best = 1e30;
    for (int it = 0; it < iters; it++) {
        double t0 = now_s();
        parallel_for(nchunks, threads, xcorr_chunk, NULL);
        double dt = now_s() - t0;
        if (dt < best) best = dt;
    }
    return best;
}

int main(int argc, char **argv) {
    int threads = argc > 1 ? atoi(argv[1]) : 4;

    for (int t = 0; t <= 2 * RAD; t++) C2[t] = (t == RAD) ? -2.0 : 1.0 / (1 + abs(t - RAD));

    /* diffusion2d 2048^2, r=3: rows-per-block sweep */
    N2 = 2048; P2 = N2 + 2 * RAD;
    SRC = calloc((size_t)P2 * P2, sizeof(double));
    DST = calloc((size_t)P2 * P2, sizeof(double));
    for (int i = 0; i < P2 * P2; i++) SRC[i] = (i * 31 % 13) - 6.0;
    int defblk = (N2 + 4 * threads - 1) / (4 * threads); /* default: 4 blocks/thread */
    printf("diffusion2d %dx%d r=%d threads=%d\n", N2, N2, RAD, threads);
    int rpbs[] = {1, 2, 4, 8, 16, 64, defblk, N2};
    for (unsigned k = 0; k < sizeof(rpbs) / sizeof(rpbs[0]); k++) {
        double s = bench_diff2(rpbs[k], rpbs[k] == N2 ? 1 : threads, 7);
        printf("  rows/block %5d%s: %8.3f ms  %7.1f Melem/s\n",
               rpbs[k], rpbs[k] == defblk ? " (ov4)" : rpbs[k] == N2 ? " (serial)" : "",
               s * 1e3, (double)N2 * N2 / s / 1e6);
    }

    /* xcorr1d 2^24, r=3: chunk sweep */
    NX1 = 1 << 24; RX1 = 3;
    FPAD = malloc(((size_t)NX1 + 2 * RX1) * sizeof(double));
    OUT = malloc((size_t)NX1 * sizeof(double));
    for (int i = 0; i < NX1 + 2 * RX1; i++) FPAD[i] = (i * 17 % 11) - 5.0;
    for (int t = 0; t <= 2 * RX1; t++) TAPS[t] = 0.1 * (t + 1);
    printf("xcorr1d n=2^24 r=%d threads=%d\n", RX1, threads);
    int chunks[] = {1024, 4096, 8192, 32768, 131072, 1 << 20};
    for (unsigned k = 0; k < sizeof(chunks) / sizeof(chunks[0]); k++) {
        double s = bench_xcorr(chunks[k], threads, 7);
        printf("  chunk %7d%s: %8.3f ms  %7.1f Melem/s\n",
               chunks[k], chunks[k] == 8192 ? " (default)" : "",
               s * 1e3, (double)NX1 / s / 1e6);
    }
    return 0;
}
