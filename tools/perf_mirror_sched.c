/* Structural perf mirror of ISSUE 6's head-of-line-blocking fix.
 *
 * Mirrors the daemon's queue in its two generations:
 *
 *   fifo  — strict arrival order; a long session admitted mid-stream
 *           makes every later short job inherit its remaining runtime
 *           as queueing delay (the seed behavior).
 *   sched — cost-aware: pop argmin(predicted_s - waited_s * AGING)
 *           (shortest-predicted-first with aging), plus step-granularity
 *           preemption — between steps the driver pops a queued job
 *           whose predicted cost is under PREEMPT_RATIO of the active
 *           job's predicted remaining cost and runs it to completion
 *           before resuming (the parked job's buffers stay live).
 *
 * Traffic mirrors the `daemon-stream-mixed` bench case: 20 cheap conv1d
 * sweeps with one expensive long session injected after three-quarters
 * of the arrivals (late-but-not-last: the blocked jobs must be a
 * MINORITY of samples for the p95/p50 ratio to witness the fix — block
 * a majority and FIFO's median is poisoned too), staggered 1 ms apart,
 * one driver (single shard). Predicted cost comes from a calibrated
 * per-element rate, mirroring the admission-time cost model. We report
 * per-job submit->done latency p50/p95 (linear interpolation, the
 * percentile_linear convention) under both policies. Numbers feed
 * EXPERIMENTS.md §Perf/L3-12; the Rust daemon reproduces the same
 * queue/driver structure, so the relative fifo-vs-sched behavior
 * carries over even though absolute times do not.
 *
 * gcc -O3 -march=native -pthread perf_mirror_sched.c -o perf_mirror_sched -lm
 */
#define _GNU_SOURCE
#include <math.h>
#include <pthread.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

/* ---------------- the work: radius-3 1-D stencil sweeps ---------------- */
#define RAD 3
typedef struct {
    int id, n, steps;
    double pred_s;    /* admission-time estimate: elems*steps*rate */
    double arrival;   /* submit instant */
    double latency;   /* submit -> done */
    int preemptions;
} job_t;

static void sweep(double *src, double *dst, int n) {
    static const double w[RAD + 1] = {-2.5, 1.4, -0.2, 0.03};
    for (int i = RAD; i < n - RAD; i++) {
        double acc = 2.0 * w[0] * src[i];
        for (int k = 1; k <= RAD; k++) acc += w[k] * (src[i - k] + src[i + k]);
        dst[i] = src[i] + 1e-4 * acc;
    }
    for (int i = 0; i < RAD; i++) { dst[i] = src[i]; dst[n - 1 - i] = src[n - 1 - i]; }
}

/* ---------------- bounded queue + policy ------------------------------- */
#define AGING 0.25
#define PREEMPT_RATIO 0.5
#define MAXQ 64

typedef struct {
    pthread_mutex_t mu;
    pthread_cond_t nonempty;
    job_t *q[MAXQ];
    int len, closed, cost_aware;
} queue_t;

static void q_init(queue_t *q, int cost_aware) {
    pthread_mutex_init(&q->mu, NULL);
    pthread_cond_init(&q->nonempty, NULL);
    q->len = 0; q->closed = 0; q->cost_aware = cost_aware;
}

static void q_push(queue_t *q, job_t *j) {
    pthread_mutex_lock(&q->mu);
    q->q[q->len++] = j;
    pthread_cond_broadcast(&q->nonempty);
    pthread_mutex_unlock(&q->mu);
}

static void q_close(queue_t *q) {
    pthread_mutex_lock(&q->mu);
    q->closed = 1;
    pthread_cond_broadcast(&q->nonempty);
    pthread_mutex_unlock(&q->mu);
}

/* policy's pick among queued jobs; call with mu held */
static int pick(queue_t *q) {
    if (q->len == 0) return -1;
    if (!q->cost_aware) return 0; /* arrival order == insertion order */
    double now = now_s(), best_key = INFINITY;
    int best = 0;
    for (int i = 0; i < q->len; i++) {
        double key = q->q[i]->pred_s - (now - q->q[i]->arrival) * AGING;
        if (key < best_key) { best_key = key; best = i; }
    }
    return best;
}

static job_t *q_take(queue_t *q, int i) {
    job_t *j = q->q[i];
    memmove(&q->q[i], &q->q[i + 1], (size_t)(q->len - i - 1) * sizeof(job_t *));
    q->len--;
    return j;
}

static job_t *q_pop(queue_t *q) {
    pthread_mutex_lock(&q->mu);
    for (;;) {
        int i = pick(q);
        if (i >= 0) { job_t *j = q_take(q, i); pthread_mutex_unlock(&q->mu); return j; }
        if (q->closed) { pthread_mutex_unlock(&q->mu); return NULL; }
        pthread_cond_wait(&q->nonempty, &q->mu);
    }
}

static job_t *q_try_pop_preempting(queue_t *q, double remaining_s) {
    if (!q->cost_aware) return NULL;
    job_t *j = NULL;
    pthread_mutex_lock(&q->mu);
    int i = pick(q);
    if (i >= 0 && q->q[i]->pred_s < remaining_s * PREEMPT_RATIO) j = q_take(q, i);
    pthread_mutex_unlock(&q->mu);
    return j;
}

/* ---------------- the driver loop (run_one with preemption) ------------ */
static void run_one(queue_t *q, job_t *j) {
    double *a = malloc((size_t)j->n * sizeof(double));
    double *b = malloc((size_t)j->n * sizeof(double));
    for (int i = 0; i < j->n; i++) a[i] = ((i * 31) % 13);
    double per_step = j->pred_s / j->steps;
    for (int s = 0; s < j->steps; s++) {
        sweep(a, b, j->n);
        double *t = a; a = b; b = t;
        if (s + 1 == j->steps) break;
        double remaining = per_step * (j->steps - s - 1);
        job_t *shortj;
        while ((shortj = q_try_pop_preempting(q, remaining)) != NULL) {
            j->preemptions++;
            run_one(q, shortj); /* parked: a/b stay live on this stack */
        }
    }
    j->latency = now_s() - j->arrival;
    free(a); free(b);
}

static void *driver(void *arg) {
    queue_t *q = (queue_t *)arg;
    job_t *j;
    while ((j = q_pop(q)) != NULL) run_one(q, j);
    return NULL;
}

/* ---------------- percentiles (linear interpolation, C=1) -------------- */
static int cmpd(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double pct_linear(double *xs, int n, double p) {
    double pos = p * (n - 1);
    int lo = (int)floor(pos), hi = (int)ceil(pos);
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo);
}

/* ---------------- one policy run of the mixed arrival sequence --------- */
#define SHORTS 20
#define SHORT_N 65536
#define SHORT_STEPS 2
#define LONG_N (1 << 20)
#define LONG_STEPS 120
#define STAGGER_S 1e-3

static void run_mixed(int cost_aware, double rate_s_per_elem, double *p50, double *p95,
                      int *preempts) {
    queue_t q;
    q_init(&q, cost_aware);
    job_t jobs[SHORTS + 1];
    int nj = 0;
    for (int i = 0; i < SHORTS; i++) {
        jobs[nj++] = (job_t){.n = SHORT_N, .steps = SHORT_STEPS,
                             .pred_s = rate_s_per_elem * SHORT_N * SHORT_STEPS};
    }
    /* late-but-not-last injection, same slot as the Rust bench */
    int at = 3 * SHORTS / 4;
    memmove(&jobs[at + 1], &jobs[at], (size_t)(SHORTS - at) * sizeof(job_t));
    jobs[at] = (job_t){.n = LONG_N, .steps = LONG_STEPS,
                       .pred_s = rate_s_per_elem * (double)LONG_N * LONG_STEPS};
    nj = SHORTS + 1;
    for (int i = 0; i < nj; i++) { jobs[i].id = i; jobs[i].preemptions = 0; }

    pthread_t th;
    pthread_create(&th, NULL, driver, &q);
    struct timespec st = {0, (long)(STAGGER_S * 1e9)};
    for (int i = 0; i < nj; i++) {
        jobs[i].arrival = now_s();
        q_push(&q, &jobs[i]);
        nanosleep(&st, NULL);
    }
    q_close(&q);
    pthread_join(th, NULL);

    double lat[SHORTS + 1];
    *preempts = 0;
    for (int i = 0; i < nj; i++) { lat[i] = jobs[i].latency; *preempts += jobs[i].preemptions; }
    qsort(lat, (size_t)nj, sizeof(double), cmpd);
    *p50 = pct_linear(lat, nj, 0.50);
    *p95 = pct_linear(lat, nj, 0.95);
}

int main(void) {
    /* calibrate the cost model's per-element rate from a warm sweep —
     * the structural stand-in for the HostModel prediction */
    double *a = malloc(LONG_N * sizeof(double)), *b = malloc(LONG_N * sizeof(double));
    for (int i = 0; i < LONG_N; i++) a[i] = i % 7;
    sweep(a, b, LONG_N); /* warm-up */
    double t0 = now_s();
    for (int r = 0; r < 4; r++) { sweep(a, b, LONG_N); sweep(b, a, LONG_N); }
    double rate = (now_s() - t0) / (8.0 * LONG_N);
    free(a); free(b);
    printf("=== scheduling mirror: %d conv shorts (n=%d x%d steps) + 1 long (n=%d x%d steps"
           " ~%.0f ms) at 3/4, %.0f ms stagger, 1 driver ===\n",
           SHORTS, SHORT_N, SHORT_STEPS, LONG_N, LONG_STEPS,
           rate * (double)LONG_N * LONG_STEPS * 1e3, STAGGER_S * 1e3);
    for (int rep = 0; rep < 3; rep++) {
        double fp50, fp95, sp50, sp95;
        int fpre, spre;
        run_mixed(0, rate, &fp50, &fp95, &fpre);
        run_mixed(1, rate, &sp50, &sp95, &spre);
        printf("fifo   p50 %8.3f ms  p95 %8.3f ms  ratio %8.2fx\n",
               fp50 * 1e3, fp95 * 1e3, fp95 / fp50);
        printf("sched  p50 %8.3f ms  p95 %8.3f ms  ratio %8.2fx  (%d preemptions)"
               "  p95 %.1fx lower, ratio %.1fx lower\n",
               sp50 * 1e3, sp95 * 1e3, sp95 / sp50, spre,
               fp95 / sp95, (fp95 / fp50) / (sp95 / sp50));
    }
    return 0;
}
