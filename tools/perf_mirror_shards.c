/* Structural perf mirror of ISSUE 4's concurrent-dispatch fix.
 *
 * Mirrors util/par.rs's persistent pool in its two generations:
 *
 *   gate    — one global dispatch gate; a second concurrent dispatch hits
 *             trylock, fails, and silently degrades to inline serial
 *             execution (the seed bug).
 *   sharded — S disjoint shards (worker set + job slot + steal counter
 *             each); session s pins to shard s % S, so concurrent
 *             dispatches never contend and all run multi-threaded.
 *
 * The workload is the engine's row-blocked diffusion2d sweep (radius-3
 * star, 4-blocks-per-thread decomposition). Each "session" steps its own
 * grid STEPS times while 1/2/4 sessions run concurrently; we report
 * per-session wall times, the fraction of dispatches that collapsed to
 * serial, and aggregate Melem/s. Numbers feed EXPERIMENTS.md §Perf/L3-10;
 * the Rust engine reproduces the same dispatch structure, so the
 * *relative* gate-vs-sharded behavior carries over even though absolute
 * times do not.
 *
 * gcc -O3 -march=native -pthread perf_mirror_shards.c -o perf_mirror_shards -lm
 */
#define _GNU_SOURCE
#include <math.h>
#include <pthread.h>
#include <stdatomic.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

/* ---------------- pool shard: trylock gate + atomic steal counter ------ */
typedef void (*item_fn)(int i, void *ctx);
typedef struct {
    pthread_mutex_t gate;
    atomic_int next;
    int n;
    item_fn f;
    void *ctx;
} shard_t;

static void shard_init(shard_t *s) {
    pthread_mutex_init(&s->gate, NULL);
    atomic_init(&s->next, 0);
}

static void *shard_worker(void *arg) {
    shard_t *s = (shard_t *)arg;
    for (;;) {
        int i = atomic_fetch_add(&s->next, 1);
        if (i >= s->n) break;
        s->f(i, s->ctx);
    }
    return NULL;
}

/* Dispatch on one shard whose gate the caller already holds. The real
 * pool parks persistent workers on a condvar; spawning per dispatch only
 * adds a constant cost to both pools being compared. Returns participant
 * count. */
static int shard_dispatch(shard_t *s, int n, int threads, item_fn f, void *ctx) {
    s->n = n; s->f = f; s->ctx = ctx;
    atomic_store(&s->next, 0);
    pthread_t th[16];
    int nw = threads - 1; if (nw > 16) nw = 16;
    for (int w = 0; w < nw; w++) pthread_create(&th[w], NULL, shard_worker, s);
    shard_worker(s);
    for (int w = 0; w < nw; w++) pthread_join(th[w], NULL);
    return nw + 1;
}

/* gate pool: ONE shard; busy gate => inline serial (seed behavior).
 * sharded pool: session pins its own shard => gate never contested.
 * Returns participants (1 == collapsed serial). */
static int pool_run(shard_t *shards, int nshards, int pin, int n, int threads,
                    item_fn f, void *ctx) {
    shard_t *s = &shards[pin % nshards];
    if (threads <= 1 || n <= 1 || pthread_mutex_trylock(&s->gate) != 0) {
        for (int i = 0; i < n; i++) f(i, ctx);  /* silent serial collapse */
        return 1;
    }
    int parts = shard_dispatch(s, n, threads, f, ctx);
    pthread_mutex_unlock(&s->gate);
    return parts;
}

/* ---------------- diffusion2d session (row-blocked sweep) -------------- */
#define RAD 3
typedef struct {
    int n, per, nblocks, threads;
    double *src, *dst;
    shard_t *shards;
    int nshards, pin;
    long collapsed, dispatches;
    double wall;
} session_t;

static void sweep_block(int b, void *ctx) {
    session_t *se = (session_t *)ctx;
    int n = se->n, stride = n + 2 * RAD;
    int lo = b * se->per, hi = lo + se->per;
    if (hi > n) hi = n;
    static const double w[RAD + 1] = {-2.5, 1.4, -0.2, 0.03};
    for (int j = lo; j < hi; j++) {
        const double *r = se->src + (j + RAD) * stride + RAD;
        double *o = se->dst + (j + RAD) * stride + RAD;
        for (int i = 0; i < n; i++) {
            double acc = 2.0 * w[0] * r[i];
            for (int k = 1; k <= RAD; k++)
                acc += w[k] * (r[i - k] + r[i + k] + r[i - k * stride] + r[i + k * stride]);
            o[i] = r[i] + 1e-4 * acc;
        }
    }
}

#define STEPS 40
static void *session_main(void *arg) {
    session_t *se = (session_t *)arg;
    double t0 = now_s();
    for (int s = 0; s < STEPS; s++) {
        /* 4 blocks per thread, the engine's default decomposition */
        se->nblocks = 4 * se->threads;
        se->per = (se->n + se->nblocks - 1) / se->nblocks;
        se->nblocks = (se->n + se->per - 1) / se->per;
        int parts = pool_run(se->shards, se->nshards, se->pin, se->nblocks,
                             se->threads, sweep_block, se);
        se->dispatches++;
        /* a collapse is a dispatch that ASKED for parallelism and ran
         * serial anyway; a threads==1 budget running serial is policy */
        if (parts == 1 && se->threads > 1) se->collapsed++;
        double *t = se->src; se->src = se->dst; se->dst = t;
    }
    se->wall = now_s() - t0;
    return NULL;
}

static double run_batch(const char *mode, int nshards, int sessions, int n, int threads) {
    shard_t shards[8];
    for (int i = 0; i < nshards; i++) shard_init(&shards[i]);
    session_t se[8];
    int stride = n + 2 * RAD;
    for (int s = 0; s < sessions; s++) {
        se[s].n = n; se[s].threads = threads;
        se[s].src = calloc((size_t)stride * stride, sizeof(double));
        se[s].dst = calloc((size_t)stride * stride, sizeof(double));
        for (int j = 0; j < stride; j++)
            for (int i = 0; i < stride; i++)
                se[s].src[j * stride + i] = ((i * 31 + j * 17) % 13);
        se[s].shards = shards; se[s].nshards = nshards;
        se[s].pin = s; /* gate mode: nshards==1, every session pins shard 0 */
        se[s].collapsed = 0; se[s].dispatches = 0;
    }
    double t0 = now_s();
    pthread_t th[8];
    for (int s = 0; s < sessions; s++) pthread_create(&th[s], NULL, session_main, &se[s]);
    for (int s = 0; s < sessions; s++) pthread_join(th[s], NULL);
    double wall = now_s() - t0;
    long collapsed = 0, dispatches = 0;
    double slowest = 0.0;
    for (int s = 0; s < sessions; s++) {
        collapsed += se[s].collapsed; dispatches += se[s].dispatches;
        if (se[s].wall > slowest) slowest = se[s].wall;
        free(se[s].src); free(se[s].dst);
    }
    double melem = (double)sessions * STEPS * n * n / wall / 1e6;
    printf("%-8s x%d  wall %6.3f s  slowest-session %6.3f s  collapsed %3ld/%ld  %8.1f Melem/s\n",
           mode, sessions, wall, slowest, collapsed, dispatches, melem);
    return melem;
}

int main(void) {
    int ncpu = (int)sysconf(_SC_NPROCESSORS_ONLN);
    int n = 2048;
    printf("=== concurrent-dispatch mirror: diffusion2d %dx%d, %d steps/session, %d cpus ===\n",
           n, n, STEPS, ncpu);
    for (int sessions = 1; sessions <= 4; sessions *= 2) {
        /* per-session thread budget = machine threads / sessions, floor 1 —
         * the job service's shard sizing */
        int budget = ncpu / sessions; if (budget < 1) budget = 1;
        /* gate: one shard, every session requests the FULL machine budget
         * (the seed engine's default) and the losers collapse serial */
        double g = run_batch("gate", 1, sessions, n, ncpu);
        /* sharded, service policy: one shard per session, disjoint budgets */
        double s = run_batch("sharded", sessions, sessions, n, budget);
        /* sharded, failover policy (unbound run()): full budget each, no
         * collapse, cores oversubscribed instead of silently serialized */
        double f = run_batch("failover", sessions, sessions, n, ncpu);
        printf("         x%d sharded/gate %.2fx, failover/gate %.2fx\n",
               sessions, s / g, f / g);
    }
    return 0;
}
