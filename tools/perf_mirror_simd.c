/* Structural perf mirror of the ISSUE-8 SIMD register-blocked inner
 * kernels (rust/src/stencil/simd.rs).
 *
 * "scalar" mirrors the reference per-element loops the Rust scalar path
 * keeps (one accumulator, taps in index order, scale after the sum).
 * "blocked N" mirrors the vector microkernels: 4 independent blocks of N
 * register accumulators per main-loop step (UNROLL=4), then single
 * N-blocks, then a scalar tail — the exact shape LLVM auto-vectorizes in
 * the Rust release build. Per-element operation order is preserved, so
 * every blocked result must be BIT-IDENTICAL to scalar; this mirror
 * asserts that (memcmp) before timing anything.
 *
 * -ffp-contract=off is load-bearing: rustc does not contract a*b+c into
 * fma, gcc does by default, and a contracted mirror would overstate the
 * vector win AND break the bitwise check.
 *
 * The `omp simd` pragmas on the lane loops (with -fopenmp-simd) stand in
 * for LLVM's SLP vectorizer: rustc turns the [f64; N] lane loops into
 * packed ops without annotation, while gcc 10's SLP leaves the same
 * straight-line lane code scalar (verified on the generated assembly).
 * The pragma only asserts lane independence — identical FP semantics,
 * same per-element order, so the bitwise check still must pass.
 *
 * Cases (single-threaded — these kernels are the per-thread row work):
 *   diffusion2d  4096^2 r=3 affine-taps row kernel   (BENCH diffusion2d)
 *   mhd-row      64^3 linear-gamma contraction set    (BENCH mhd-substep)
 *   crossover    diffusion row kernel at tiny row lengths
 *
 * Build/run:
 *   gcc -O3 -march=native -ffp-contract=off -fopenmp-simd -o /tmp/pms \
 *       tools/perf_mirror_simd.c -lm && /tmp/pms
 */
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

static uint64_t rng_state = 0x9e3779b97f4a7c15ull;
static double rng_norm(void) {
    rng_state ^= rng_state << 13;
    rng_state ^= rng_state >> 7;
    rng_state ^= rng_state << 17;
    return (double)(int64_t)rng_state * 5.421e-20;
}

typedef struct {
    long off;
    double c;
} tap_t;

/* ---------- scalar references (the Rust scalar path, verbatim) -------- */

/* diffusion: dst[i] = center[i] + s * sum_t c_t * data[off_t + i] */
/* restrict throughout mirrors Rust's &mut noalias guarantee — LLVM sees
 * it on every Rust kernel, so a mirror without it would handicap gcc */
static void affine_row_scalar(double *restrict dst, const double *restrict center,
                              const double *restrict data, const tap_t *taps,
                              int ntaps, double s, long n) {
    for (long i = 0; i < n; i++) {
        double acc = 0.0;
        for (int t = 0; t < ntaps; t++) acc += taps[t].c * data[taps[t].off + i];
        dst[i] = center[i] + s * acc;
    }
}

/* mhd: dst[i] = scale * sum_t w_t * data[base + i + t*stride - rad*stride] */
static void stencil_row_scalar(double *restrict dst, const double *restrict data,
                               long base, long stride, int rad, const double *w,
                               int nw, double scale, long n) {
    for (long i = 0; i < n; i++) {
        double acc = 0.0;
        for (int t = 0; t < nw; t++)
            acc += w[t] * data[base + i + (long)(t - rad) * stride];
        dst[i] = scale * acc;
    }
}

/* mhd grad-div off-diagonal: d/dx1 of d/dx2, inner-scaled then summed */
static void d1d1_row_scalar(double *restrict dst, const double *restrict data,
                            long base, long s1, long s2, int rad, const double *w1,
                            const double *w2, double inv_dx, long n) {
    for (long i = 0; i < n; i++) {
        double acc = 0.0;
        for (int t2 = 0; t2 < 2 * rad + 1; t2++) {
            double cb = w2[t2];
            if (cb == 0.0) continue;
            long mbase = base + i + (long)(t2 - rad) * s2;
            double m = 0.0;
            for (int t1 = 0; t1 < 2 * rad + 1; t1++) {
                double c = w1[t1];
                if (c == 0.0) continue;
                m += c * data[mbase + (long)(t1 - rad) * s1];
            }
            acc += cb * (m * inv_dx);
        }
        dst[i] = acc * inv_dx;
    }
}

/* ---------- register-blocked microkernels (simd.rs shape) ------------- */

#define UNROLL 4

#define DEF_BLOCKED(N)                                                         \
    static void affine_row_blocked##N(double *restrict dst,                    \
                                      const double *restrict center,           \
                                      const double *restrict data,             \
                                      const tap_t *taps,                       \
                                      int ntaps, double s, long n) {           \
        long i = 0;                                                            \
        for (; i + UNROLL * N <= n; i += UNROLL * N) {                         \
            double acc[UNROLL][N];                                             \
            for (int u = 0; u < UNROLL; u++)                                   \
                for (int l = 0; l < N; l++) acc[u][l] = 0.0;                   \
            for (int t = 0; t < ntaps; t++) {                                  \
                const double *p = data + taps[t].off + i;                      \
                double c = taps[t].c;                                          \
                for (int u = 0; u < UNROLL; u++) {                             \
                    _Pragma("omp simd")                                        \
                    for (int l = 0; l < N; l++) acc[u][l] += c * p[u * N + l]; \
                }                                                              \
            }                                                                  \
            for (int u = 0; u < UNROLL; u++) {                                 \
                _Pragma("omp simd")                                            \
                for (int l = 0; l < N; l++)                                    \
                    dst[i + u * N + l] = center[i + u * N + l] + s * acc[u][l];\
            }                                                                  \
        }                                                                      \
        for (; i + N <= n; i += N) {                                           \
            double acc[N];                                                     \
            for (int l = 0; l < N; l++) acc[l] = 0.0;                          \
            for (int t = 0; t < ntaps; t++) {                                  \
                const double *p = data + taps[t].off + i;                      \
                double c = taps[t].c;                                          \
                _Pragma("omp simd")                                            \
                for (int l = 0; l < N; l++) acc[l] += c * p[l];                \
            }                                                                  \
            _Pragma("omp simd")                                                \
            for (int l = 0; l < N; l++) dst[i + l] = center[i + l] + s * acc[l];\
        }                                                                      \
        affine_row_scalar(dst + i, center + i, data + i, taps, ntaps, s, n - i);\
    }                                                                          \
    static void stencil_row_blocked##N(double *restrict dst,                   \
                                       const double *restrict data, long base, \
                                       long stride, int rad, const double *w,  \
                                       int nw, double scale, long n) {         \
        long i = 0;                                                            \
        for (; i + UNROLL * N <= n; i += UNROLL * N) {                         \
            double acc[UNROLL][N];                                             \
            for (int u = 0; u < UNROLL; u++)                                   \
                for (int l = 0; l < N; l++) acc[u][l] = 0.0;                   \
            for (int t = 0; t < nw; t++) {                                     \
                const double *p = data + base + i + (long)(t - rad) * stride;  \
                double c = w[t];                                               \
                for (int u = 0; u < UNROLL; u++) {                             \
                    _Pragma("omp simd")                                        \
                    for (int l = 0; l < N; l++) acc[u][l] += c * p[u * N + l]; \
                }                                                              \
            }                                                                  \
            for (int u = 0; u < UNROLL; u++) {                                 \
                _Pragma("omp simd")                                            \
                for (int l = 0; l < N; l++)                                    \
                    dst[i + u * N + l] = scale * acc[u][l];                    \
            }                                                                  \
        }                                                                      \
        for (; i + N <= n; i += N) {                                           \
            double acc[N];                                                     \
            for (int l = 0; l < N; l++) acc[l] = 0.0;                          \
            for (int t = 0; t < nw; t++) {                                     \
                const double *p = data + base + i + (long)(t - rad) * stride;  \
                double c = w[t];                                               \
                _Pragma("omp simd")                                            \
                for (int l = 0; l < N; l++) acc[l] += c * p[l];                \
            }                                                                  \
            _Pragma("omp simd")                                                \
            for (int l = 0; l < N; l++) dst[i + l] = scale * acc[l];           \
        }                                                                      \
        stencil_row_scalar(dst + i, data, base + i, stride, rad, w, nw, scale, \
                           n - i);                                             \
    }                                                                          \
    static void d1d1_row_blocked##N(double *restrict dst,                      \
                                    const double *restrict data, long base,    \
                                    long s1, long s2, int rad, const double *w1,\
                                    const double *w2, double inv_dx, long n) { \
        long i = 0;                                                            \
        for (; i + N <= n; i += N) {                                           \
            double acc[N];                                                     \
            for (int l = 0; l < N; l++) acc[l] = 0.0;                          \
            for (int t2 = 0; t2 < 2 * rad + 1; t2++) {                         \
                double cb = w2[t2];                                            \
                if (cb == 0.0) continue;                                       \
                const double *pb = data + base + i + (long)(t2 - rad) * s2;    \
                _Pragma("omp simd")                                            \
                for (int l = 0; l < N; l++) {                                  \
                    double m = 0.0;                                            \
                    for (int t1 = 0; t1 < 2 * rad + 1; t1++) {                 \
                        double c = w1[t1];                                     \
                        if (c == 0.0) continue;                                \
                        m += c * pb[l + (long)(t1 - rad) * s1];                \
                    }                                                          \
                    acc[l] += cb * (m * inv_dx);                               \
                }                                                              \
            }                                                                  \
            for (int l = 0; l < N; l++) dst[i + l] = acc[l] * inv_dx;          \
        }                                                                      \
        d1d1_row_scalar(dst + i, data, base + i, s1, s2, rad, w1, w2, inv_dx,  \
                        n - i);                                                \
    }

DEF_BLOCKED(2)
DEF_BLOCKED(4)
DEF_BLOCKED(8)

/* ---------- timing ----------------------------------------------------- */

static double median3(double a, double b, double c) {
    if (a > b) { double t = a; a = b; b = t; }
    if (b > c) { double t = b; b = c; c = t; }
    if (a > b) { double t = a; a = b; b = t; }
    return b;
}

#define TIME_MEDIAN(out_s, reps, body)                                         \
    do {                                                                       \
        double samp_[3];                                                       \
        for (int s_ = 0; s_ < 3; s_++) {                                       \
            double t0_ = now_s();                                              \
            for (int r_ = 0; r_ < (reps); r_++) { body; }                      \
            samp_[s_] = (now_s() - t0_) / (reps);                              \
        }                                                                      \
        (out_s) = median3(samp_[0], samp_[1], samp_[2]);                       \
    } while (0)

static int bits_equal(const double *a, const double *b, long n) {
    return memcmp(a, b, (size_t)n * sizeof(double)) == 0;
}

/* second-derivative weights, radius 3 (rust Diffusion order-3 table) */
static const double C2[7] = {1.0 / 90, -3.0 / 20, 3.0 / 2, -49.0 / 18,
                             3.0 / 2,  -3.0 / 20, 1.0 / 90};
/* first-derivative weights, radius 3 (center weight 0 -> pruned) */
static const double C1[7] = {-1.0 / 60, 3.0 / 20, -3.0 / 4, 0.0,
                             3.0 / 4,   -3.0 / 20, 1.0 / 60};

int main(void) {
    /* -------- diffusion2d 4096^2 r=3 (BENCH diffusion2d) -------------- */
    {
        const long n = 4096, rad = 3;
        const long px = n + 2 * rad;
        double *data = malloc((size_t)(px * px) * sizeof(double));
        double *dst = malloc((size_t)n * sizeof(double));
        double *ref = malloc((size_t)n * sizeof(double));
        for (long i = 0; i < px * px; i++) data[i] = rng_norm();
        tap_t taps[14];
        int nt = 0;
        long strides[2] = {1, px};
        for (int ax = 0; ax < 2; ax++)
            for (int t = 0; t < 7; t++)
                taps[nt++] = (tap_t){(long)(t - 3) * strides[ax], C2[t]};
        const double s = 0.1;
        long row0 = rad * px + rad;

        affine_row_scalar(ref, data + row0, data + row0, taps, nt, s, n);
        affine_row_blocked4(dst, data + row0, data + row0, taps, nt, s, n);
        if (!bits_equal(ref, dst, n)) { puts("FAIL diffusion blocked4 parity"); return 1; }
        affine_row_blocked8(dst, data + row0, data + row0, taps, nt, s, n);
        if (!bits_equal(ref, dst, n)) { puts("FAIL diffusion blocked8 parity"); return 1; }
        affine_row_blocked2(dst, data + row0, data + row0, taps, nt, s, n);
        if (!bits_equal(ref, dst, n)) { puts("FAIL diffusion blocked2 parity"); return 1; }
        puts("diffusion2d row kernel: blocked{2,4,8} bit-identical to scalar");

        /* time a full sweep: n interior rows */
        double t_sc, t_b2, t_b4, t_b8;
        TIME_MEDIAN(t_sc, 3, for (long j = 0; j < n; j++) {
            long b = row0 + j * px;
            affine_row_scalar(dst, data + b, data + b, taps, nt, s, n);
        });
        TIME_MEDIAN(t_b2, 3, for (long j = 0; j < n; j++) {
            long b = row0 + j * px;
            affine_row_blocked2(dst, data + b, data + b, taps, nt, s, n);
        });
        TIME_MEDIAN(t_b4, 3, for (long j = 0; j < n; j++) {
            long b = row0 + j * px;
            affine_row_blocked4(dst, data + b, data + b, taps, nt, s, n);
        });
        TIME_MEDIAN(t_b8, 3, for (long j = 0; j < n; j++) {
            long b = row0 + j * px;
            affine_row_blocked8(dst, data + b, data + b, taps, nt, s, n);
        });
        double e = (double)n * n / 1e6;
        printf("diffusion2d 4096^2 r=3 sweep (1 thread):\n");
        printf("  scalar   %7.2f ms  %7.1f Melem/s\n", t_sc * 1e3, e / t_sc);
        printf("  blocked2 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b2 * 1e3, e / t_b2, t_sc / t_b2);
        printf("  blocked4 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b4 * 1e3, e / t_b4, t_sc / t_b4);
        printf("  blocked8 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b8 * 1e3, e / t_b8, t_sc / t_b8);
        free(data); free(dst); free(ref);
    }

    /* -------- mhd 64^3 linear-gamma contraction set (BENCH mhd) ------- */
    {
        const long n = 64, rad = 3;
        const long px = n + 2 * rad, pxy = px * px;
        double *data = malloc((size_t)(px * px * px) * sizeof(double));
        double *dst = malloc((size_t)n * sizeof(double));
        double *acc = malloc((size_t)n * sizeof(double));
        double *ref = malloc((size_t)n * sizeof(double));
        for (long i = 0; i < px * px * px; i++) data[i] = rng_norm();
        long strides[3] = {1, px, pxy};
        const double inv_dx2 = 104.187, inv_dx = 10.2;
        long row0 = rad + px * (rad + px * rad);

        /* the fused substep's per-row linear part, one field row at a
         * time: 8 Laplacians (3 axis contractions each) + 3 grad-div
         * components (1 diagonal + 2 off-diagonal d1d1 each) = 33
         * stencil contractions/row; with the Laplacian's per-axis taps
         * that is ~60 weighted 7-tap reductions per row. */
#define MHD_ROW(STENCIL, D1D1, base)                                           \
        do {                                                                   \
            for (int f = 0; f < 8; f++) {                                      \
                for (int ax = 0; ax < 3; ax++) {                               \
                    STENCIL(f == 0 && ax == 0 ? acc : dst, data, (base),       \
                            strides[ax], rad, C2, 7, inv_dx2, n);              \
                    if (!(f == 0 && ax == 0))                                  \
                        for (long i = 0; i < n; i++) acc[i] += dst[i];         \
                }                                                              \
            }                                                                  \
            for (int c = 0; c < 3; c++) {                                      \
                STENCIL(dst, data, (base), strides[c], rad, C2, 7, inv_dx2, n);\
                for (long i = 0; i < n; i++) acc[i] += dst[i];                 \
                for (int o = 0; o < 3; o++) {                                  \
                    if (o == c) continue;                                      \
                    D1D1(dst, data, (base), strides[c], strides[o], rad, C1,   \
                         C1, inv_dx, n);                                       \
                    for (long i = 0; i < n; i++) acc[i] += dst[i];             \
                }                                                              \
            }                                                                  \
        } while (0)

        MHD_ROW(stencil_row_scalar, d1d1_row_scalar, row0);
        memcpy(ref, acc, (size_t)n * sizeof(double));
        MHD_ROW(stencil_row_blocked4, d1d1_row_blocked4, row0);
        if (!bits_equal(ref, acc, n)) { puts("FAIL mhd blocked4 parity"); return 1; }
        MHD_ROW(stencil_row_blocked8, d1d1_row_blocked8, row0);
        if (!bits_equal(ref, acc, n)) { puts("FAIL mhd blocked8 parity"); return 1; }
        puts("mhd row contractions: blocked{4,8} bit-identical to scalar");

        double t_sc, t_b2, t_b4, t_b8;
        TIME_MEDIAN(t_sc, 2, for (long k = 0; k < n; k++) for (long j = 0; j < n; j++)
            MHD_ROW(stencil_row_scalar, d1d1_row_scalar, row0 + j * px + k * pxy));
        TIME_MEDIAN(t_b2, 2, for (long k = 0; k < n; k++) for (long j = 0; j < n; j++)
            MHD_ROW(stencil_row_blocked2, d1d1_row_blocked2, row0 + j * px + k * pxy));
        TIME_MEDIAN(t_b4, 2, for (long k = 0; k < n; k++) for (long j = 0; j < n; j++)
            MHD_ROW(stencil_row_blocked4, d1d1_row_blocked4, row0 + j * px + k * pxy));
        TIME_MEDIAN(t_b8, 2, for (long k = 0; k < n; k++) for (long j = 0; j < n; j++)
            MHD_ROW(stencil_row_blocked8, d1d1_row_blocked8, row0 + j * px + k * pxy));
        double e = (double)n * n * n / 1e6;
        printf("mhd 64^3 linear-gamma contractions (1 thread):\n");
        printf("  scalar   %7.2f ms  %7.1f Melem/s\n", t_sc * 1e3, e / t_sc);
        printf("  blocked2 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b2 * 1e3, e / t_b2, t_sc / t_b2);
        printf("  blocked4 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b4 * 1e3, e / t_b4, t_sc / t_b4);
        printf("  blocked8 %7.2f ms  %7.1f Melem/s  x%.2f\n", t_b8 * 1e3, e / t_b8, t_sc / t_b8);
        free(data); free(dst); free(acc); free(ref);
    }

    /* -------- scalar-vs-blocked crossover at small row lengths -------- */
    {
        const long rad = 3, px = 4096 + 2 * rad;
        double *data = malloc((size_t)(px * 16) * sizeof(double));
        double *dst = malloc((size_t)4096 * sizeof(double));
        for (long i = 0; i < px * 16; i++) data[i] = rng_norm();
        tap_t taps[14];
        int nt = 0;
        long strides[2] = {1, px};
        for (int ax = 0; ax < 2; ax++)
            for (int t = 0; t < 7; t++)
                taps[nt++] = (tap_t){(long)(t - 3) * strides[ax], C2[t]};
        const double s = 0.1;
        long row0 = rad * px + rad;
        printf("crossover: diffusion row kernel, scalar vs blocked8, per row length\n");
        printf("  %6s %12s %12s %8s\n", "n", "scalar ns", "blocked8 ns", "speedup");
        long lens[] = {8, 16, 32, 48, 64, 128, 256, 1024, 4096};
        for (unsigned li = 0; li < sizeof(lens) / sizeof(lens[0]); li++) {
            long n = lens[li];
            int reps = (int)(40000000 / (n + 64));
            double t_sc, t_b8;
            TIME_MEDIAN(t_sc, reps,
                        affine_row_scalar(dst, data + row0, data + row0, taps, nt, s, n));
            TIME_MEDIAN(t_b8, reps,
                        affine_row_blocked8(dst, data + row0, data + row0, taps, nt, s, n));
            printf("  %6ld %12.1f %12.1f %7.2fx\n", n, t_sc * 1e9, t_b8 * 1e9,
                   t_sc / t_b8);
        }
        free(data); free(dst);
    }
    return 0;
}
