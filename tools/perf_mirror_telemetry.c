/* Structural mirror of the PR 10 telemetry layer's hot-path cost (see
 * rust/src/util/telemetry.rs SpanRing::record and DESIGN.md §18): every
 * instrumented chunk of stencil work pays one relaxed fetch_add on the
 * ring cursor, three relaxed payload stores, one release stamp store,
 * and one relaxed counter fetch_add — nothing else. This mirror runs
 * the two serving workloads' inner loops bare and instrumented at the
 * real chunk granularity (one span per row-block / k-slab, like the
 * sharded pool's dispatch chunks) and reports the overhead.
 *
 * Measures, per workload:
 *   - bare median step time
 *   - instrumented median step time (ring writes + counter bumps armed)
 *   - overhead percentage — the DESIGN.md §18 budget pins this < 1%
 *
 * Build/run: gcc -O3 -march=native -pthread -o /tmp/pmt tools/perf_mirror_telemetry.c -lm && /tmp/pmt
 */
#include <math.h>
#include <stdatomic.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <time.h>

#define R 3
#define RING_SPANS 4096
#define CHUNK_ROWS 64 /* rows per dispatched chunk, like par.rs chunking */

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + 1e-9 * ts.tv_nsec;
}

static uint64_t now_us(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (uint64_t)ts.tv_sec * 1000000u + (uint64_t)(ts.tv_nsec / 1000);
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double median(double *xs, int n) {
    qsort(xs, n, sizeof(double), cmp_d);
    return n % 2 ? xs[n / 2] : 0.5 * (xs[n / 2 - 1] + xs[n / 2]);
}

/* ---- the telemetry mirror: one preallocated seqlock ring ------------- */

typedef struct {
    _Atomic uint64_t meta, t0, t1, stamp;
} slot_t;

static slot_t ring[RING_SPANS];
static _Atomic uint64_t cursor;
static _Atomic uint64_t counter; /* e.g. Counters::completed */

/* SpanRing::record: fetch_add + three relaxed stores + release stamp */
static inline void span_record(uint64_t kind, uint64_t job, uint64_t t0, uint64_t t1) {
    uint64_t seq = atomic_fetch_add_explicit(&cursor, 1, memory_order_relaxed);
    slot_t *s = &ring[seq & (RING_SPANS - 1)];
    atomic_store_explicit(&s->meta, kind | (job << 8), memory_order_relaxed);
    atomic_store_explicit(&s->t0, t0, memory_order_relaxed);
    atomic_store_explicit(&s->t1, t1, memory_order_relaxed);
    atomic_store_explicit(&s->stamp, seq + 1, memory_order_release);
}

/* ---- workload 1: diffusion2d r=3, 4096^2 ----------------------------- */

static void diff2d_step(const double *src, double *dst, int n, int instrument) {
    const int p = n + 2 * R;
    static const double w[2 * R + 1] = {1. / 90, -3. / 20, 3. / 2, -49. / 18,
                                        3. / 2,  -3. / 20, 1. / 90};
    for (int i0 = R; i0 < n + R; i0 += CHUNK_ROWS) {
        uint64_t t0 = instrument ? now_us() : 0;
        int i1 = i0 + CHUNK_ROWS < n + R ? i0 + CHUNK_ROWS : n + R;
        for (int i = i0; i < i1; i++) {
            for (int j = R; j < n + R; j++) {
                double acc = 0.0;
                for (int k = -R; k <= R; k++) {
                    acc += w[k + R] * src[i * p + j + k];
                    acc += w[k + R] * src[(i + k) * p + j];
                }
                dst[i * p + j] = src[i * p + j] + 1e-3 * acc;
            }
        }
        if (instrument) {
            span_record(2 /* Chunk */, (uint64_t)i0, t0, now_us());
            atomic_fetch_add_explicit(&counter, 1, memory_order_relaxed);
        }
    }
}

/* ---- workload 2: MHD-like 8-field fused update, 64^3 ----------------- */

#define NF 8

static void mhd_step(const double *src, double *dst, int n, int instrument) {
    const int p = n + 2; /* r=1 halo per field */
    const long fstride = (long)p * p * p;
    for (int k0 = 1; k0 <= n; k0 += 8) { /* one span per k-slab chunk */
        uint64_t t0 = instrument ? now_us() : 0;
        int k1 = k0 + 8 <= n + 1 ? k0 + 8 : n + 1;
        for (int f = 0; f < NF; f++) {
            const double *s = src + f * fstride;
            double *d = dst + f * fstride;
            /* cross-field coupling term, like the fused substep */
            const double *o = src + ((f + 1) % NF) * fstride;
            for (int k = k0; k < k1; k++)
                for (int i = 1; i <= n; i++)
                    for (int j = 1; j <= n; j++) {
                        long c = (long)k * p * p + i * p + j;
                        double lap = s[c - 1] + s[c + 1] + s[c - p] + s[c + p] +
                                     s[c - p * p] + s[c + p * p] - 6.0 * s[c];
                        d[c] = s[c] + 1e-3 * lap + 1e-4 * o[c];
                    }
        }
        if (instrument) {
            span_record(2 /* Chunk */, (uint64_t)k0, t0, now_us());
            atomic_fetch_add_explicit(&counter, 1, memory_order_relaxed);
        }
    }
}

typedef void (*stepper_t)(const double *, double *, int, int);

#define SAMPLES 60

/* Direct cost of one instrumented chunk's hooks, measured in a tight
 * loop: two clock reads + one ring record + one counter bump. This is
 * the per-chunk tax the serving loop actually pays, and dividing it
 * into the step time gives a *deterministic* overhead bound — the A/B
 * step comparison below oscillates +-2% around zero on a shared box,
 * an order of magnitude above the effect it tries to measure. */
static double hook_cost_s(void) {
    const int iters = 200000;
    for (int i = 0; i < 1000; i++) span_record(2, i, now_us(), now_us()); /* warmup */
    double t0 = now_s();
    for (int i = 0; i < iters; i++) {
        uint64_t a = now_us();
        uint64_t b = now_us();
        span_record(2, (uint64_t)i, a, b);
        atomic_fetch_add_explicit(&counter, 1, memory_order_relaxed);
    }
    return (now_s() - t0) / iters;
}

/* Interleave bare and instrumented steps A/B/A/B through one long run:
 * thermal drift, frequency scaling, and page-cache state hit both modes
 * identically, so the median difference isolates the hook cost. */
static void bench(const char *name, stepper_t step, long elems, int n, int chunks,
                  double hook_s) {
    double *a = calloc((size_t)elems, sizeof(double));
    double *b = calloc((size_t)elems, sizeof(double));
    if (!a || !b) { fprintf(stderr, "alloc failed\n"); exit(1); }
    for (long i = 0; i < elems; i++) a[i] = ((i * 31) % 13) * 0.1;

    for (int s = 0; s < 4; s++) step(s % 2 ? b : a, s % 2 ? a : b, n, s % 2); /* warmup */
    double bare_t[SAMPLES], inst_t[SAMPLES];
    for (int s = 0; s < 2 * SAMPLES; s++) {
        int instrument = s % 2;
        double t0 = now_s();
        step(s % 2 ? b : a, s % 2 ? a : b, n, instrument);
        double dt = now_s() - t0;
        if (instrument) inst_t[s / 2] = dt;
        else bare_t[s / 2] = dt;
    }
    double bare = median(bare_t, SAMPLES), inst = median(inst_t, SAMPLES);

    double ab = (inst - bare) / bare * 100.0;
    double bound = chunks * hook_s / bare * 100.0;
    printf("%-14s n=%-5d bare %8.3f ms  instr %8.3f ms  A/B delta %+6.3f%%  "
           "hook bound %7.4f%%  %s\n",
           name, n, bare * 1e3, inst * 1e3, ab, bound,
           bound < 1.0 ? "PASS (<1%)" : "FAIL");
    free(a);
    free(b);
}

int main(void) {
    printf("telemetry hot-path mirror: seqlock ring write + counter bump per chunk\n");
    printf("ring %d slots, %d rows/chunk (2d), 8-plane k-slabs (3d)\n\n", RING_SPANS,
           CHUNK_ROWS);
    double hook_s = hook_cost_s();
    printf("one chunk's hooks (2 clock reads + ring record + counter bump): %.1f ns\n\n",
           hook_s * 1e9);
    int n2 = 4096;
    bench("diffusion2d", diff2d_step, (long)(n2 + 2 * R) * (n2 + 2 * R), n2,
          (n2 + CHUNK_ROWS - 1) / CHUNK_ROWS, hook_s);
    int n3 = 64;
    bench("mhd-fused", mhd_step, (long)NF * (n3 + 2) * (n3 + 2) * (n3 + 2), n3,
          (n3 + 7) / 8, hook_s);
    printf("\nspans recorded: %llu, counter: %llu (kept live so stores aren't elided)\n",
           (unsigned long long)atomic_load(&cursor), (unsigned long long)atomic_load(&counter));
    return 0;
}
