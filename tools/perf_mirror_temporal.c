/* Structural perf mirror of the ISSUE-9 trapezoidal temporal tiling
 * (rust/src/stencil/temporal.rs, rust/src/stencil/conv.rs chain path).
 *
 * Two cases, mirroring the two temporal paths the Rust engine grew:
 *
 * 1. xcorr-chain: `stages` successive radius-r cross-correlations of one
 *    1-D signal. "staged" mirrors the reference chain (each stage streams
 *    the whole array once: `stages` full memory passes). "chunked"
 *    mirrors xcorr1d_chain_plan: each 8192-element output chunk advances
 *    through ALL stages while cache-resident — stage s computes
 *    (stages-1-s)*2r extra elements per side (the 1-D trapezoid), the
 *    input is read once per chunk. This is the steps-per-residency win
 *    temporal blocking exists for.
 *
 * 2. diffusion2d-chunk: the full-domain widened-scratch chunk of
 *    TemporalScheduler::advance_chunk — copy the interior into a scratch
 *    pair with ghost width depth*r, periodic-fill the ghosts ONCE, run
 *    `depth` sweeps over shrinking bands (sweep s writes every cell
 *    within (depth-1-s)*r of the interior), copy back. The scratch is
 *    the same size as the field, so per-step traffic is 2 + 4/depth
 *    passes against the classic loop's 2 + ghost fill: the chunk
 *    amortizes ghost fills and loop launches but PAYS copy-in/out. The
 *    mirror measures where that trades (small cache-resident fields)
 *    and where it loses (streaming-sized fields) — the reason depth is
 *    a TUNED LaunchPlan axis with depth 1 kept in the candidate set,
 *    not an always-on transform.
 *
 * Both paths are gated on bitwise parity with their reference before any
 * timing is taken (memcmp): the trapezoid computes every intermediate
 * cell from the same periodic extension the classic loop sees, and
 * -ffp-contract=off matches rustc's no-contraction FP semantics.
 *
 * Build/run:
 *   gcc -O3 -march=native -ffp-contract=off -o /tmp/pmt \
 *       tools/perf_mirror_temporal.c -lm && /tmp/pmt
 */
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* deterministic input, matches the Rust mirrors' idiom */
static void seed_fill(double *a, size_t n, uint64_t salt) {
    uint64_t s = 0x243F6A8885A308D3ull ^ salt;
    for (size_t i = 0; i < n; i++) {
        s = s * 6364136223846793005ull + 1442695040888963407ull;
        a[i] = (double)((s >> 33) % 4096) / 2048.0 - 1.0;
    }
}

/* ------------------------------------------------------------------ */
/* case 1: 1-D xcorr chain — staged whole-array vs chunked trapezoid   */
/* ------------------------------------------------------------------ */

#define R 3
#define TAPS (2 * R + 1)
#define CHUNK 8192

/* one stage over [0, len): out[i] = sum_j taps[j] * in[i + j]
 * (tap-major accumulation order preserved in both paths) */
static void xcorr_span(double *out, const double *in, const double *taps,
                       size_t len) {
    for (size_t i = 0; i < len; i++) {
        double acc = 0.0;
        for (int j = 0; j < TAPS; j++)
            acc += taps[j] * in[i + (size_t)j];
        out[i] = acc;
    }
}

/* reference: each stage streams the whole array once */
static void chain_staged(double *out, const double *fpad, const double *taps,
                         size_t n, int stages, double *work) {
    size_t len = n + (size_t)(stages) * 2 * R; /* padded input length */
    const double *src = fpad;
    double *a = work, *b = work + len;
    for (int s = 0; s < stages; s++) {
        len -= 2 * R;
        double *dst = (s == stages - 1) ? out : a;
        xcorr_span(dst, src, taps, len);
        src = dst;
        double *t = a; a = b; b = t;
    }
}

/* temporal: every output chunk runs all stages while cache-resident;
 * stage s computes (stages-1-s)*2R extra elements per side */
static void chain_chunked(double *out, const double *fpad, const double *taps,
                          size_t n, int stages, double *work) {
    size_t maxw = CHUNK + (size_t)(stages) * 2 * R;
    double *a = work, *b = work + maxw;
    for (size_t lo = 0; lo < n; lo += CHUNK) {
        size_t len = (lo + CHUNK <= n) ? CHUNK : n - lo;
        const double *src = fpad + lo;
        size_t w = len + (size_t)(stages - 1) * 2 * R; /* stage-0 output width */
        for (int s = 0; s < stages; s++) {
            double *dst = (s == stages - 1) ? out + lo : a;
            xcorr_span(dst, src, taps, w);
            src = dst;
            w -= 2 * R;
            double *t = a; a = b; b = t;
        }
    }
}

/* ------------------------------------------------------------------ */
/* case 2: diffusion2d — classic per-step loop vs widened-ghost chunk  */
/* ------------------------------------------------------------------ */

/* padded 2-D field, ghost width g; idx(i,j) for i,j in [-g, n+g) */
static inline size_t gidx(size_t stride, int g, int i, int j) {
    return (size_t)(i + g) * stride + (size_t)(j + g);
}

static void fill_ghosts(double *f, int n, int g) {
    size_t stride = (size_t)n + 2 * (size_t)g;
    /* x (column) wrap inside every interior row, then whole-row y wrap:
     * same order as Grid::fill_ghosts — corners come from the y pass */
    for (int i = 0; i < n; i++)
        for (int j = 0; j < g; j++) {
            f[gidx(stride, g, i, -1 - j)] = f[gidx(stride, g, i, n - 1 - j)];
            f[gidx(stride, g, i, n + j)] = f[gidx(stride, g, i, j)];
        }
    for (int i = 0; i < g; i++) {
        memcpy(&f[gidx(stride, g, -1 - i, -g)], &f[gidx(stride, g, n - 1 - i, -g)],
               stride * sizeof(double));
        memcpy(&f[gidx(stride, g, n + i, -g)], &f[gidx(stride, g, i, -g)],
               stride * sizeof(double));
    }
}

/* one sweep of the radius-R star over the band [-e, n+e)^2 — the exact
 * affine-taps op order of the Rust row kernel: x taps in index order,
 * then y taps, scale after the sum */
static void diff_sweep(double *dst, const double *src, int n, int g, int e,
                       const double *ctaps, double w0) {
    size_t stride = (size_t)n + 2 * (size_t)g;
    for (int i = -e; i < n + e; i++)
        for (int j = -e; j < n + e; j++) {
            double acc = 0.0;
            for (int t = -R; t <= R; t++)
                acc += ctaps[t + R] * src[gidx(stride, g, i, j + t)];
            for (int t = -R; t <= R; t++)
                acc += ctaps[t + R] * src[gidx(stride, g, i + t, j)];
            dst[gidx(stride, g, i, j)] = w0 * src[gidx(stride, g, i, j)] + acc;
        }
}

/* classic: ghost fill + full-interior sweep, once per step */
static void diff_classic(double **cur, double **next, int n, int steps,
                         const double *ctaps, double w0) {
    for (int s = 0; s < steps; s++) {
        fill_ghosts(*cur, n, R);
        diff_sweep(*next, *cur, n, R, 0, ctaps, w0);
        double *t = *cur; *cur = *next; *next = t;
    }
}

/* temporal chunk: copy into depth*R-wide scratch, fill ghosts once,
 * depth sweeps over shrinking bands, copy back */
static void diff_chunked(double **cur, double **next, int n, int steps,
                         int depth, const double *ctaps, double w0,
                         double *sa, double *sb) {
    size_t fstride = (size_t)n + 2 * R;
    for (int done = 0; done < steps;) {
        int c = steps - done < depth ? steps - done : depth;
        if (c == 1) { /* degenerate chunk: classic step (as in Rust) */
            diff_classic(cur, next, n, 1, ctaps, w0);
            done += 1;
            continue;
        }
        /* the scratch layout follows THIS chunk's ghost width (a tail
         * chunk shorter than `depth` gets a narrower halo, as in Rust) */
        int g = c * R;
        size_t stride = (size_t)n + 2 * (size_t)g;
        for (int i = 0; i < n; i++)
            memcpy(&sa[gidx(stride, g, i, 0)], &(*cur)[gidx(fstride, R, i, 0)],
                   (size_t)n * sizeof(double));
        fill_ghosts(sa, n, g);
        double *a = sa, *b = sb;
        for (int s = 0; s < c; s++) {
            int e = (c - 1 - s) * R;
            diff_sweep(b, a, n, g, e, ctaps, w0);
            double *t = a; a = b; b = t;
        }
        for (int i = 0; i < n; i++)
            memcpy(&(*cur)[gidx(fstride, R, i, 0)], &a[gidx(stride, g, i, 0)],
                   (size_t)n * sizeof(double));
        done += c;
    }
}

/* ------------------------------------------------------------------ */

int main(void) {
    /* -------- case 1: xcorr chain ---------------------------------- */
    {
        size_t n = (size_t)1 << 22;
        int stages = 4;
        size_t npad = n + (size_t)(stages) * 2 * R;
        double *fpad = malloc(npad * sizeof(double));
        double *want = malloc(n * sizeof(double));
        double *got = malloc(n * sizeof(double));
        double *work = malloc(2 * npad * sizeof(double));
        double taps[TAPS];
        seed_fill(fpad, npad, 1);
        seed_fill(taps, TAPS, 2);

        chain_staged(want, fpad, taps, n, stages, work);
        chain_chunked(got, fpad, taps, n, stages, work);
        if (memcmp(want, got, n * sizeof(double)) != 0) {
            fprintf(stderr, "FATAL: chunked xcorr chain is not bit-identical\n");
            return 1;
        }

        printf("xcorr-chain n=2^22 r=%d stages=%d (per full chain):\n", R, stages);
        int reps = 9;
        double best_staged = 1e30, best_chunked = 1e30;
        for (int i = 0; i < reps; i++) {
            double t0 = now_s();
            chain_staged(want, fpad, taps, n, stages, work);
            double t1 = now_s();
            chain_chunked(got, fpad, taps, n, stages, work);
            double t2 = now_s();
            if (t1 - t0 < best_staged) best_staged = t1 - t0;
            if (t2 - t1 < best_chunked) best_chunked = t2 - t1;
        }
        printf("  staged   %8.2f ms  %7.1f Melem/s  1.00x\n",
               best_staged * 1e3, (double)n * stages / best_staged / 1e6);
        printf("  chunked  %8.2f ms  %7.1f Melem/s  %.2fx\n",
               best_chunked * 1e3, (double)n * stages / best_chunked / 1e6,
               best_staged / best_chunked);
    }

    /* -------- case 2: diffusion2d chunk ---------------------------- */
    {
        double ctaps[TAPS];
        seed_fill(ctaps, TAPS, 3);
        for (int t = 0; t < TAPS; t++) ctaps[t] *= 1e-2; /* keep it stable */
        double w0 = 0.75;
        int sizes[] = {96, 384, 1536};
        int steps = 8;
        for (size_t si = 0; si < sizeof(sizes) / sizeof(sizes[0]); si++) {
            int n = sizes[si];
            int maxg = 4 * R;
            size_t fbytes = ((size_t)n + 2 * R) * ((size_t)n + 2 * R) * sizeof(double);
            size_t sbytes =
                ((size_t)n + 2 * maxg) * ((size_t)n + 2 * maxg) * sizeof(double);
            double *cur = malloc(fbytes), *next = malloc(fbytes);
            double *ref = malloc(fbytes), *refn = malloc(fbytes);
            double *sa = malloc(sbytes), *sb = malloc(sbytes);
            seed_fill(cur, fbytes / sizeof(double), 4 + (uint64_t)n);
            memcpy(ref, cur, fbytes);
            memcpy(next, cur, fbytes);
            memcpy(refn, cur, fbytes);
            memset(sa, 0, sbytes);
            memset(sb, 0, sbytes);

            double *rc = ref, *rn = refn;
            diff_classic(&rc, &rn, n, steps, ctaps, w0);
            printf("diffusion2d %d^2 r=%d, %d steps (per-step ns/elem):\n", n, R,
                   steps);
            for (int depth = 1; depth <= 4; depth++) {
                double *cc = malloc(fbytes), *cn = malloc(fbytes);
                memcpy(cc, cur, fbytes);
                memcpy(cn, cur, fbytes);
                double *pc = cc, *pn = cn;
                diff_chunked(&pc, &pn, n, steps, depth, ctaps, w0, sa, sb);
                /* compare interiors bit for bit */
                size_t fstride = (size_t)n + 2 * R;
                for (int i = 0; i < n; i++)
                    if (memcmp(&pc[gidx(fstride, R, i, 0)],
                               &rc[gidx(fstride, R, i, 0)],
                               (size_t)n * sizeof(double)) != 0) {
                        fprintf(stderr,
                                "FATAL: depth %d diverged at n=%d row %d\n",
                                depth, n, i);
                        return 1;
                    }
                int reps = n <= 400 ? 40 : 6;
                double best = 1e30;
                for (int rep = 0; rep < reps; rep++) {
                    memcpy(cc, cur, fbytes);
                    memcpy(cn, cur, fbytes);
                    pc = cc; pn = cn;
                    double t0 = now_s();
                    diff_chunked(&pc, &pn, n, steps, depth, ctaps, w0, sa, sb);
                    double t1 = now_s();
                    if (t1 - t0 < best) best = t1 - t0;
                }
                static double d1;
                if (depth == 1) d1 = best;
                printf("  depth %d  %8.2f ns/elem  %.2fx\n", depth,
                       best / steps / ((double)n * n) * 1e9, d1 / best);
                free(cc);
                free(cn);
            }
            free(cur); free(next); free(ref); free(refn); free(sa); free(sb);
        }
    }
    return 0;
}
