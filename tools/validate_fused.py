"""Validation mirror for rust/src/stencil/mhd/fused.rs.

Two independent implementations of one MHD RK3 substep:
  * reference: mirrors ops.rs apply_axis / d1d1-with-ghost-refill / rhs.rs
    eval + rk3.rs substep_reference (vectorized numpy on padded arrays)
  * fused: a literal port of fused.rs (flat arrays, identical index math,
    per-row stencil_row / d1d1_row / gdiv_row helpers, scalar phi)
They must agree to machine precision across substeps l=0,1,2.
"""
import numpy as np

R = 3
C1 = np.array([-1 / 60, 3 / 20, -3 / 4, 0.0, 3 / 4, -3 / 20, 1 / 60])
C2 = np.array([1 / 90, -3 / 20, 3 / 2, -49 / 18, 3 / 2, -3 / 20, 1 / 90])

# params (MhdParams with dx=0.37)
cs0, gamma, cp, rho0 = 1.0, 5 / 3, 1.0, 1.0
nu, eta, zeta, mu0, kappa = 5e-3, 5e-3, 0.3, 1.0, 1e-3  # zeta nonzero to exercise that term
dx = 0.37
inv_dx = 1.0 / dx
ln_rho0 = np.log(rho0)
temp0 = cs0 * cs0 / (cp * (gamma - 1.0))

LNRHO, UX, UY, UZ, SS, AXF, AYF, AZF = range(8)
NF = 8

nx, ny, nz = 9, 7, 5
px, py, pz = nx + 2 * R, ny + 2 * R, nz + 2 * R

rng = np.random.default_rng(42)


def pad_periodic(interior):  # interior shape (nz, ny, nx)
    return np.pad(interior, R, mode="wrap")


def interior(padded):
    return padded[R:R + nz, R:R + ny, R:R + nx]


AXIS = {0: 2, 1: 1, 2: 0}  # rust axis -> numpy axis (x fastest => last)


def shifted(padded, ax, off):
    sl = [slice(R, R + nz), slice(R, R + ny), slice(R, R + nx)]
    a = AXIS[ax]
    s = sl[a]
    sl[a] = slice(s.start + off, s.stop + off)
    return padded[tuple(sl)]


# ---------------------------------------------------------------- reference
def apply_axis(padded, ax, w, scale):
    out = np.zeros((pz, py, px))
    oi = interior(out)
    for t, c in enumerate(w):
        if c == 0.0:
            continue
        oi += c * shifted(padded, ax, t - R)
    oi *= scale
    return out


def d1(padded, ax):
    return apply_axis(padded, ax, C1, inv_dx)


def d2(padded, ax):
    return apply_axis(padded, ax, C2, inv_dx * inv_dx)


def laplacian(padded):
    acc = d2(padded, 0)
    for ax in (1, 2):
        interior(acc)[...] += interior(d2(padded, ax))
    return acc


def d1d1(padded, ax1, ax2):
    mid = d1(padded, ax1)
    mid = pad_periodic(interior(mid))  # the reference's ghost refill
    return d1(mid, ax2)


def reference_rhs(state_padded):
    lnrho, ss = state_padded[LNRHO], state_padded[SS]
    uu = [state_padded[UX + a] for a in range(3)]
    aa = [state_padded[AXF + a] for a in range(3)]
    glr = [interior(d1(lnrho, a)) for a in range(3)]
    gs = [interior(d1(ss, a)) for a in range(3)]
    lap_lnrho = interior(laplacian(lnrho))
    lap_ss = interior(laplacian(ss))
    duv = [[interior(d1(uu[i], j)) for j in range(3)] for i in range(3)]
    lap_u = [interior(laplacian(uu[i])) for i in range(3)]

    def gdiv(vv, i):
        acc = np.zeros((nz, ny, nx))
        for j in range(3):
            t = d2(vv[j], i) if i == j else d1d1(vv[j], j, i)
            acc += interior(t)
        return acc

    gdivu = [gdiv(uu, i) for i in range(3)]
    dav = [[interior(d1(aa[i], j)) for j in range(3)] for i in range(3)]
    lap_a = [interior(laplacian(aa[i])) for i in range(3)]
    gdiva = [gdiv(aa, i) for i in range(3)]

    lnrho_v, ss_v = interior(lnrho), interior(ss)
    u = [interior(uu[a]) for a in range(3)]
    divu = duv[0][0] + duv[1][1] + duv[2][2]
    rho = np.exp(lnrho_v)
    inv_rho = np.exp(-lnrho_v)
    exparg = gamma * ss_v / cp + (gamma - 1.0) * (lnrho_v - ln_rho0)
    cs2 = cs0 * cs0 * np.exp(exparg)
    temp = temp0 * np.exp(exparg)
    bb = [dav[2][1] - dav[1][2], dav[0][2] - dav[2][0], dav[1][0] - dav[0][1]]
    jv = [(gdiva[a] - lap_a[a]) / mu0 for a in range(3)]
    jxb = [jv[1] * bb[2] - jv[2] * bb[1], jv[2] * bb[0] - jv[0] * bb[2],
           jv[0] * bb[1] - jv[1] * bb[0]]
    uxb = [u[1] * bb[2] - u[2] * bb[1], u[2] * bb[0] - u[0] * bb[2],
           u[0] * bb[1] - u[1] * bb[0]]
    s_t = [[0.5 * (duv[a][b] + duv[b][a]) - (divu / 3.0 if a == b else 0.0)
            for b in range(3)] for a in range(3)]
    s2 = np.zeros((nz, ny, nx))
    s_glnrho = [np.zeros((nz, ny, nx)) for _ in range(3)]
    for a in range(3):
        for b in range(3):
            s2 += s_t[a][b] * s_t[a][b]
            s_glnrho[a] += s_t[a][b] * glr[b]

    cell = [None] * NF
    cell[LNRHO] = -(u[0] * glr[0] + u[1] * glr[1] + u[2] * glr[2]) - divu
    for a in range(3):
        adv = -(u[0] * duv[a][0] + u[1] * duv[a][1] + u[2] * duv[a][2])
        press = -cs2 * (gs[a] / cp + glr[a])
        lorentz = jxb[a] * inv_rho
        visc = nu * (lap_u[a] + gdivu[a] / 3.0 + 2.0 * s_glnrho[a]) + zeta * gdivu[a]
        cell[UX + a] = adv + press + lorentz + visc
    glnt = [gamma / cp * gs[a] + (gamma - 1.0) * glr[a] for a in range(3)]
    lap_lnt = gamma / cp * lap_ss + (gamma - 1.0) * lap_lnrho
    div_k_gradt = kappa * temp * (lap_lnt + glnt[0] ** 2 + glnt[1] ** 2 + glnt[2] ** 2)
    j2 = jv[0] ** 2 + jv[1] ** 2 + jv[2] ** 2
    heat = div_k_gradt + eta * mu0 * j2 + 2.0 * rho * nu * s2 + zeta * rho * divu * divu
    cell[SS] = -(u[0] * gs[0] + u[1] * gs[1] + u[2] * gs[2]) + heat * inv_rho / temp
    for a in range(3):
        cell[AXF + a] = uxb[a] + eta * lap_a[a]
    return cell


# -------------------------------------------------------------------- fused
def stencil_row(dst, data, base, stride, rad, w, scale):
    dst[:] = 0.0
    n = len(dst)
    for t in range(len(w)):
        c = w[t]
        if c == 0.0:
            continue
        off = base + t * stride - rad * stride
        dst += c * data[off:off + n]
    dst *= scale


def add_rows(dst, src):
    dst += src


def d1d1_row(dst, tmp, data, base, s1, s2, rad, c1, invdx):
    dst[:] = 0.0
    for t2 in range(len(c1)):
        cb = c1[t2]
        if cb == 0.0:
            continue
        mbase = base + t2 * s2 - rad * s2
        stencil_row(tmp, data, mbase, s1, rad, c1, invdx)
        dst += cb * tmp
    dst *= invdx


def laplacian_row(dst, tmp, data, base, strides, rad, c2, invdx2):
    stencil_row(dst, data, base, strides[0], rad, c2, invdx2)
    for st in strides[1:]:
        stencil_row(tmp, data, base, st, rad, c2, invdx2)
        add_rows(dst, tmp)


def gdiv_row(dst, tmp, tmp2, vec_data, i, base, strides, rad, c1, c2, invdx):
    dst[:] = 0.0
    for jf in range(3):
        if i == jf:
            stencil_row(tmp, vec_data[jf], base, strides[i], rad, c2, invdx * invdx)
        else:
            d1d1_row(tmp, tmp2, vec_data[jf], base, strides[jf], strides[i], rad, c1, invdx)
        add_rows(dst, tmp)


(B_GLNRHO, B_GSS, B_LAP_LNRHO, B_LAP_SS, B_DU, B_LAP_U, B_GDIVU, B_DA,
 B_LAP_A, B_GDIVA, B_TMP, B_TMP2, B_ROWS) = (0, 3, 6, 7, 8, 17, 20, 23, 32, 35, 38, 39, 40)


def substep_fused(sd, wflat, dflat, alpha, beta, dt):
    # sd: list of NF flat padded arrays; wflat/dflat: flat padded arrays (written)
    strides = [1, px, px * py]
    rad = R
    ud = [sd[UX], sd[UY], sd[UZ]]
    ad = [sd[AXF], sd[AYF], sd[AZF]]
    buf = np.zeros(B_ROWS * nx)

    def rowm(b):
        return buf[b * nx:(b + 1) * nx]

    tmp, tmp2 = rowm(B_TMP), rowm(B_TMP2)
    for k in range(nz):
        for j in range(ny):
            base = R + px * ((j + R) + py * (k + R))
            for ax in range(3):
                stencil_row(rowm(B_GLNRHO + ax), sd[LNRHO], base, strides[ax], rad, C1, inv_dx)
                stencil_row(rowm(B_GSS + ax), sd[SS], base, strides[ax], rad, C1, inv_dx)
            laplacian_row(rowm(B_LAP_LNRHO), tmp, sd[LNRHO], base, strides, rad, C2, inv_dx ** 2)
            laplacian_row(rowm(B_LAP_SS), tmp, sd[SS], base, strides, rad, C2, inv_dx ** 2)
            for a in range(3):
                for b in range(3):
                    stencil_row(rowm(B_DU + 3 * a + b), ud[a], base, strides[b], rad, C1, inv_dx)
                    stencil_row(rowm(B_DA + 3 * a + b), ad[a], base, strides[b], rad, C1, inv_dx)
                laplacian_row(rowm(B_LAP_U + a), tmp, ud[a], base, strides, rad, C2, inv_dx ** 2)
                laplacian_row(rowm(B_LAP_A + a), tmp, ad[a], base, strides, rad, C2, inv_dx ** 2)
                gdiv_row(rowm(B_GDIVU + a), tmp, tmp2, ud, a, base, strides, rad, C1, C2, inv_dx)
                gdiv_row(rowm(B_GDIVA + a), tmp, tmp2, ad, a, base, strides, rad, C1, C2, inv_dx)

            def rb(b, i):
                return buf[b * nx + i]

            def sv(f, i):
                return sd[f][base + i]

            for i in range(nx):
                lnrho_v, ss_v = sv(LNRHO, i), sv(SS, i)
                u = [sv(UX, i), sv(UY, i), sv(UZ, i)]
                glr = [rb(B_GLNRHO, i), rb(B_GLNRHO + 1, i), rb(B_GLNRHO + 2, i)]
                gs = [rb(B_GSS, i), rb(B_GSS + 1, i), rb(B_GSS + 2, i)]
                duv = [[rb(B_DU + 3 * a + b, i) for b in range(3)] for a in range(3)]
                divu = duv[0][0] + duv[1][1] + duv[2][2]
                rho = np.exp(lnrho_v)
                inv_rho = np.exp(-lnrho_v)
                exparg = gamma * ss_v / cp + (gamma - 1.0) * (lnrho_v - ln_rho0)
                cs2 = cs0 * cs0 * np.exp(exparg)
                temp = temp0 * np.exp(exparg)
                dav = [[rb(B_DA + 3 * a + b, i) for b in range(3)] for a in range(3)]
                bb = [dav[2][1] - dav[1][2], dav[0][2] - dav[2][0], dav[1][0] - dav[0][1]]
                jv = [(rb(B_GDIVA + a, i) - rb(B_LAP_A + a, i)) / mu0 for a in range(3)]
                jxb = [jv[1] * bb[2] - jv[2] * bb[1], jv[2] * bb[0] - jv[0] * bb[2],
                       jv[0] * bb[1] - jv[1] * bb[0]]
                uxb = [u[1] * bb[2] - u[2] * bb[1], u[2] * bb[0] - u[0] * bb[2],
                       u[0] * bb[1] - u[1] * bb[0]]
                s_t = [[0.0] * 3 for _ in range(3)]
                for a in range(3):
                    for b in range(3):
                        s_t[a][b] = 0.5 * (duv[a][b] + duv[b][a])
                        if a == b:
                            s_t[a][b] -= divu / 3.0
                s2 = 0.0
                s_glnrho = [0.0] * 3
                for a in range(3):
                    for b in range(3):
                        s2 += s_t[a][b] * s_t[a][b]
                        s_glnrho[a] += s_t[a][b] * glr[b]
                cell = [0.0] * NF
                cell[LNRHO] = -(u[0] * glr[0] + u[1] * glr[1] + u[2] * glr[2]) - divu
                for a in range(3):
                    adv = -(u[0] * duv[a][0] + u[1] * duv[a][1] + u[2] * duv[a][2])
                    press = -cs2 * (gs[a] / cp + glr[a])
                    lorentz = jxb[a] * inv_rho
                    visc = nu * (rb(B_LAP_U + a, i) + rb(B_GDIVU + a, i) / 3.0
                                 + 2.0 * s_glnrho[a]) + zeta * rb(B_GDIVU + a, i)
                    cell[UX + a] = adv + press + lorentz + visc
                glnt = [gamma / cp * gs[a] + (gamma - 1.0) * glr[a] for a in range(3)]
                lap_lnt = gamma / cp * rb(B_LAP_SS, i) + (gamma - 1.0) * rb(B_LAP_LNRHO, i)
                div_k_gradt = kappa * temp * (lap_lnt + glnt[0] ** 2 + glnt[1] ** 2 + glnt[2] ** 2)
                j2 = jv[0] ** 2 + jv[1] ** 2 + jv[2] ** 2
                heat = (div_k_gradt + eta * mu0 * j2 + 2.0 * rho * nu * s2
                        + zeta * rho * divu * divu)
                cell[SS] = -(u[0] * gs[0] + u[1] * gs[1] + u[2] * gs[2]) + heat * inv_rho / temp
                for a in range(3):
                    cell[AXF + a] = uxb[a] + eta * rb(B_LAP_A + a, i)
                for f in range(NF):
                    wv = alpha * wflat[f][base + i] + dt * cell[f]
                    wflat[f][base + i] = wv
                    dflat[f][base + i] = sv(f, i) + beta * wv


# ------------------------------------------------------------------- driver
RK3_ALPHA = [0.0, -5.0 / 9.0, -153.0 / 128.0]
RK3_BETA = [1.0 / 3.0, 15.0 / 16.0, 8.0 / 15.0]
dt = 1e-3

init = [1e-2 * rng.standard_normal((nz, ny, nx)) for _ in range(NF)]

# reference trajectory
ref_state = [i.copy() for i in init]
ref_w = [np.zeros((nz, ny, nx)) for _ in range(NF)]
# fused trajectory (flat padded arrays)
fus_state = [i.copy() for i in init]
fus_w = [np.zeros(px * py * pz) for _ in range(NF)]

for l in range(3):
    # reference substep
    sp = np.stack([pad_periodic(f) for f in ref_state])
    cell = reference_rhs(sp)
    for f in range(NF):
        wv = RK3_ALPHA[l] * ref_w[f] + dt * cell[f]
        ref_w[f] = wv
        ref_state[f] = ref_state[f] + RK3_BETA[l] * wv

    # fused substep
    sd = [pad_periodic(f).ravel().copy() for f in fus_state]
    dflat = [np.zeros(px * py * pz) for _ in range(NF)]
    substep_fused(sd, fus_w, dflat, RK3_ALPHA[l], RK3_BETA[l], dt)
    fus_state = [interior(d.reshape(pz, py, px)).copy() for d in dflat]

    err = max(np.max(np.abs(ref_state[f] - fus_state[f])) for f in range(NF))
    werr = max(np.max(np.abs(ref_w[f] - interior(fus_w[f].reshape(pz, py, px))))
               for f in range(NF))
    scale = max(np.max(np.abs(ref_state[f])) for f in range(NF))
    print(f"substep {l}: state err {err:.3e}  w err {werr:.3e}  (scale {scale:.3e})")
    assert err < 1e-13 and werr < 1e-13, "fused diverged from reference"

print("OK: fused algorithm matches the unfused reference")
